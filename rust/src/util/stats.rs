//! Descriptive statistics used by the bench harness and experiment reports.

/// A summary of a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.50),
            p05: percentile_sorted(&sorted, 0.05),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rel_std(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean.abs() }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford). Used where samples are too
/// numerous to buffer (e.g. per-request latency in the coordinator).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold another accumulator in (Chan's parallel update), so per-shard
    /// series can merge into one distribution at snapshot time.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest observation; `None` on an empty series (the sentinel init
    /// values are never exposed to callers).
    pub fn min(&self) -> Option<f64> {
        (self.n > 0).then_some(self.min)
    }

    /// Largest observation; `None` on an empty series.
    pub fn max(&self) -> Option<f64> {
        (self.n > 0).then_some(self.max)
    }
}

/// Log-bucketed (HDR-style) histogram for non-negative samples — latency
/// seconds in practice. Buckets are geometrically spaced at a factor of
/// [`LogHistogram::GROWTH`] = 2^(1/8) per bucket, so any quantile estimate
/// is within one bucket's relative error (≈ 9%) of the nearest-rank exact
/// quantile, at a few hundred `u64`s of memory regardless of sample count.
/// Exact mean/std/min/max ride along in an embedded [`Welford`].
#[derive(Clone, Debug, Default)]
pub struct LogHistogram {
    stats: Welford,
    /// `buckets[i]` counts samples in `[MIN_VALUE·g^i, MIN_VALUE·g^(i+1))`,
    /// grown lazily up to [`LogHistogram::MAX_BUCKETS`].
    buckets: Vec<u64>,
}

impl LogHistogram {
    /// Per-bucket growth factor: 2^(1/8), i.e. 8 buckets per octave.
    pub const GROWTH: f64 = 1.090_507_732_665_257_7;
    /// Smallest resolvable sample (1 ns); anything below lands in bucket 0.
    pub const MIN_VALUE: f64 = 1e-9;
    /// Bucket-count cap; the top bucket absorbs overflow (≈ 10^10 s — no
    /// real latency gets there).
    pub const MAX_BUCKETS: usize = 512;

    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    fn bucket_index(x: f64) -> usize {
        if x <= Self::MIN_VALUE {
            return 0;
        }
        let idx = ((x / Self::MIN_VALUE).log2() * 8.0).floor() as usize;
        idx.min(Self::MAX_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — the representative value quantile
    /// queries report.
    fn bucket_mid(i: usize) -> f64 {
        Self::MIN_VALUE * Self::GROWTH.powf(i as f64 + 0.5)
    }

    pub fn push(&mut self, x: f64) {
        let x = if x.is_finite() { x.max(0.0) } else { 0.0 };
        self.stats.push(x);
        let idx = Self::bucket_index(x);
        if idx >= self.buckets.len() {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
    }

    /// Fold another histogram in (bucket-wise add + Welford merge).
    pub fn merge(&mut self, other: &LogHistogram) {
        self.stats.merge(&other.stats);
        if other.buckets.len() > self.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (dst, &src) in self.buckets.iter_mut().zip(&other.buckets) {
            *dst += src;
        }
    }

    pub fn count(&self) -> u64 {
        self.stats.count()
    }

    pub fn mean(&self) -> f64 {
        self.stats.mean()
    }

    pub fn std(&self) -> f64 {
        self.stats.std()
    }

    pub fn min(&self) -> Option<f64> {
        self.stats.min()
    }

    pub fn max(&self) -> Option<f64> {
        self.stats.max()
    }

    /// Nearest-rank quantile estimate, `q` in `[0, 1]`: the midpoint of the
    /// bucket holding the `⌈q·n⌉`-th smallest sample, clamped to the exact
    /// observed `[min, max]`. Within a factor of √[`Self::GROWTH`] of the
    /// true order statistic by construction. `None` on an empty series.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let n = self.stats.count();
        if n == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let mid = Self::bucket_mid(i);
                // min/max are exact, so clamping can only tighten the bound.
                return Some(mid.clamp(self.stats.min, self.stats.max));
            }
        }
        self.max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let s = Summary::of(&xs);
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), Some(s.min));
        assert_eq!(w.max(), Some(s.max));
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn welford_empty_min_max_are_none() {
        let w = Welford::new();
        assert_eq!(w.count(), 0);
        assert_eq!(w.min(), None, "empty min must not leak the +inf sentinel");
        assert_eq!(w.max(), None, "empty max must not leak the -inf sentinel");
        let mut w = w;
        w.push(3.0);
        assert_eq!(w.min(), Some(3.0));
        assert_eq!(w.max(), Some(3.0));
    }

    #[test]
    fn welford_merge_matches_single_pass() {
        let xs: Vec<f64> = (0..200).map(|i| (i as f64 * 0.7).cos() * 5.0 + 2.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let (a, b) = xs.split_at(73);
        let (mut wa, mut wb) = (Welford::new(), Welford::new());
        a.iter().for_each(|&x| wa.push(x));
        b.iter().for_each(|&x| wb.push(x));
        wa.merge(&wb);
        assert_eq!(wa.count(), whole.count());
        assert!((wa.mean() - whole.mean()).abs() < 1e-9);
        assert!((wa.std() - whole.std()).abs() < 1e-9);
        assert_eq!(wa.min(), whole.min());
        assert_eq!(wa.max(), whole.max());
        // Merging an empty accumulator is the identity, both ways.
        wa.merge(&Welford::new());
        assert_eq!(wa.count(), 200);
        let mut empty = Welford::new();
        empty.merge(&whole);
        assert_eq!(empty.count(), 200);
        assert_eq!(empty.min(), whole.min());
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn histogram_basics() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.min(), None);
        let mut h = h;
        for x in [0.001, 0.002, 0.003] {
            h.push(x);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 0.002).abs() < 1e-12, "mean is exact (Welford)");
        assert_eq!(h.min(), Some(0.001));
        assert_eq!(h.max(), Some(0.003));
        // Single-bucket degenerate cases clamp to the exact extremes.
        let p50 = h.quantile(0.5).unwrap();
        assert!(p50 >= 0.001 && p50 <= 0.003, "{p50}");
        // Zero and sub-resolution samples are representable, not panics.
        h.push(0.0);
        h.push(1e-12);
        assert_eq!(h.count(), 5);
        assert_eq!(h.min(), Some(0.0));
    }

    #[test]
    fn histogram_merge_equals_single_feed() {
        let xs: Vec<f64> = (1..300).map(|i| i as f64 * 17e-6).collect();
        let mut whole = LogHistogram::new();
        xs.iter().for_each(|&x| whole.push(x));
        let (a, b) = xs.split_at(101);
        let (mut ha, mut hb) = (LogHistogram::new(), LogHistogram::new());
        a.iter().for_each(|&x| ha.push(x));
        b.iter().for_each(|&x| hb.push(x));
        ha.merge(&hb);
        assert_eq!(ha.count(), whole.count());
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(ha.quantile(q), whole.quantile(q), "q={q}");
        }
        assert_eq!(ha.min(), whole.min());
        assert_eq!(ha.max(), whole.max());
        assert!((ha.mean() - whole.mean()).abs() < 1e-12);
    }

    /// The histogram's accuracy contract: p50/p95/p99 within one bucket's
    /// relative error of the exact nearest-rank quantile, over random
    /// workloads spanning several orders of magnitude.
    #[test]
    fn histogram_quantiles_within_one_bucket_of_exact() {
        crate::util::proptest::property("log-histogram quantile error", 64, |rng| {
            let n = rng.index(400) + 1;
            let mut h = LogHistogram::new();
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                // Log-uniform over [1 µs, 10 s]: the serving-latency range.
                let x = 1e-6 * 10f64.powf(rng.uniform() as f64 * 7.0);
                h.push(x);
                xs.push(x);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.5, 0.95, 0.99] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = xs[rank - 1];
                let est = h.quantile(q).unwrap();
                // One bucket's relative error: the estimate and the exact
                // order statistic share a bucket, so their ratio is bounded
                // by the bucket growth factor.
                let ratio = est / exact;
                assert!(
                    ratio >= 1.0 / LogHistogram::GROWTH && ratio <= LogHistogram::GROWTH,
                    "q={q} n={n} exact={exact} est={est} ratio={ratio}"
                );
            }
        });
    }
}
