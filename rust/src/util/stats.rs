//! Descriptive statistics used by the bench harness and experiment reports.

/// A summary of a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 0.50),
            p05: percentile_sorted(&sorted, 0.05),
            p95: percentile_sorted(&sorted, 0.95),
        }
    }

    /// Relative standard deviation (coefficient of variation).
    pub fn rel_std(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean.abs() }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, `q` in `[0,1]`.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Online mean/variance accumulator (Welford). Used where samples are too
/// numerous to buffer (e.g. per-request latency in the coordinator).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for fewer than two samples.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 10.0);
    }

    #[test]
    fn welford_matches_summary() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let s = Summary::of(&xs);
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.std() - s.std).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }
}
