//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014, `pcg32_random_r` reference constants): small state,
//! excellent statistical quality, and — critically for reproduction work —
//! fully deterministic across platforms. Every experiment in this crate takes
//! an explicit seed and threads it through one of these.

/// A PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of mantissa entropy.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of mantissa entropy.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire rejection method).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let l = m as u32;
            if l >= bound || l >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        assert!(bound <= u32::MAX as usize);
        self.below(bound as u32) as usize
    }

    /// Standard normal via Box–Muller (one value per call; the pair's twin is
    /// discarded to keep the generator allocation-free and stateless).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Normal with mean `mu` and standard deviation `sigma`.
    #[inline]
    pub fn normal_scaled(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal()
    }

    /// Bernoulli draw with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a slice with `N(0, sigma^2)` values (the paper's weight init).
    pub fn fill_normal(&mut self, xs: &mut [f32], sigma: f32) {
        for x in xs.iter_mut() {
            *x = self.normal() * sigma;
        }
    }

    /// Split off an independent generator (derives a new stream from the
    /// current state; the parent is advanced so successive splits differ).
    pub fn split(&mut self) -> Pcg32 {
        let seed = self.next_u64();
        let stream = self.next_u64();
        Pcg32::new(seed, stream)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 50_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg32::seeded(11);
        let hits = (0..20_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 20_000.0;
        assert!((rate - 0.3).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Pcg32::seeded(123);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
