//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple start/lap timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Timer { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction), and reset the lap.
    pub fn lap_s(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Human-readable duration, e.g. `1.23s`, `45.6ms`, `789us`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.0}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(45)), "45.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(789)), "789us");
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
    }

    #[test]
    fn lap_advances() {
        let mut t = Timer::start();
        let a = t.lap_s();
        let b = t.lap_s();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(t.elapsed_s() >= a);
    }
}
