//! `condcomp` — the launcher.
//!
//! Subcommands:
//!   train       train a network (native engine), optionally with an
//!               activation estimator in the loop
//!   train-pjrt  train through the AOT train_step artifact (three-layer path)
//!   serve       start the serving coordinator (native or PJRT backend);
//!               loads the machine profile named by `autotune.profile_path`
//!               (or `--autotune-profile`), recalibrates any cost column the
//!               profile lacks for a newly registered kernel, and logs the
//!               per-layer dispatch threshold + kernel-choice tables,
//!               falling back to online calibration. `--kernels` restricts
//!               the registry allow-list. The batching front-end is sharded
//!               (`--shards`, 0 = derived from the thread budget; `--router`
//!               round-robin|least-depth); per-request outputs are
//!               bit-identical for any shard count, lease width, and
//!               kernel allow-list
//!   worker      headless single-shard replica of `serve`: trains the same
//!               deterministic model (same profile/seed ⇒ bit-identical
//!               weights across processes) and serves the TCP protocol; a
//!               coordinator (`serve --worker-addrs …`) verifies it via the
//!               `hello` handshake (protocol version + model fingerprint +
//!               machine profile) and routes batches to it
//!   calibrate   measure per-layer per-kernel dispatch cost columns for a
//!               profile's architecture on this machine and persist them as
//!               a machine-profile JSON (`autotune.profile_path`); `serve`
//!               loads the file at startup so the measurement happens once
//!               per machine, not once per process. Budget via
//!               `--budget-ms` / `autotune.budget_ms`; kernel set via
//!               `--kernels`.
//!   trace       fetch the flight-recorder ring from a running server (the
//!               `trace` protocol op): the last N batch records with
//!               per-span timings. Recording requires the server to run
//!               with `--trace` / `server.trace` / `CONDCOMP_TRACE=1`
//!   experiment  regenerate a paper table/figure (fig2…fig6, table2, table3,
//!               speedup, all)
//!   bench       measured dense-vs-masked-vs-parallel sweep; writes
//!               machine-readable BENCH_parallel.json including fitted
//!               per-layer thresholds for the chosen profile's shapes
//!   bench-flops print the §3.4 analytic cost model for an architecture
//!   datagen     dump a synthetic corpus to .npy (debugging/external use)
//!
//! Every subcommand accepts `--threads N` to size the shared compute pool
//! (0 = auto). Each parallel kernel matches its serial oracle within its
//! declared equivalence tier — bit-exact for the scalar kernels, a bounded
//! ULP tolerance for the `*_simd` kernels, aggregate sign agreement for
//! the int8 `*_i8` kernels (which route only when explicitly allow-listed)
//! — and is individually deterministic, so for a fixed dispatch policy the
//! knob changes wall-clock only, never results. (`CONDCOMP_FORCE_SCALAR=1`
//! pins the SIMD kernels to their scalar mirrors, which is bit-identical
//! to the vector path by construction; the int8 kernels' i32 accumulators
//! are exact, so their ISA paths are bit-identical everywhere.) The one
//! caveat is `serve`: its startup
//! *calibration* is a timing measurement, so across runs the dispatch
//! policy may pick a different (tier-equivalent) kernel near the threshold
//! density.

use condcomp::autotune::{Autotuner, MachineProfile};
use condcomp::cli::{Command, OptSpec, Parsed};
use condcomp::condcomp::{KernelId, KernelRegistry};
use condcomp::config::{EstimatorConfig, ExperimentProfile};
use condcomp::coordinator::{Backend, NativeBackend, RemoteBackend, RemoteOpts, Server, ServerConfig};
use condcomp::cost::LayerCost;
use condcomp::data::synth::build_dataset;
use condcomp::estimator::SignEstimatorSet;
use condcomp::nn::mlp::NoGater;
use condcomp::nn::trainer::evaluate_error;
use condcomp::nn::{Mlp, Trainer};
use condcomp::runtime::{Engine, ModelRuntime};
use condcomp::util::Pcg32;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    format!(
        "condcomp {} — conditional feedforward computation via low-rank sign estimation\n\
         \n\
         usage: condcomp <train|train-pjrt|serve|worker|trace|calibrate|experiment|bench|bench-flops|datagen> [options]\n\
         \n\
         run `condcomp <subcommand> --help` for options.\n",
        condcomp::VERSION
    )
}

/// Apply the `--threads` knob (shared by every subcommand), falling back to
/// the profile's `train.threads` config key when the flag is 0/absent. Only
/// *requests* the size — the pool itself is created lazily on first use, so
/// a later knob in the same process is not silently shadowed.
fn apply_threads(parsed: &Parsed, config_threads: usize) -> anyhow::Result<usize> {
    let cli = parsed.get_usize("threads")?.unwrap_or(0);
    let requested = if cli != 0 { cli } else { config_threads };
    condcomp::parallel::configure_global(requested);
    Ok(if requested == 0 {
        condcomp::parallel::default_threads()
    } else {
        requested
    })
}

/// Resolve the kernel allow-list: CLI `--kernels` wins, then the profile's
/// `dispatch.kernels` config key; `None` = every registered kernel. Unknown
/// ids fail loudly here, before anything starts serving.
fn kernel_allowlist(
    parsed: &Parsed,
    profile: &ExperimentProfile,
) -> anyhow::Result<Option<Vec<KernelId>>> {
    let parsed_ids = match parsed.get("kernels") {
        Some(s) => KernelRegistry::parse_allowlist(s).map(Some),
        None if !profile.dispatch.kernels.is_empty() => {
            KernelRegistry::parse_ids(&profile.dispatch.kernels).map(Some)
        }
        None => Ok(None),
    };
    parsed_ids.map_err(|e| anyhow::anyhow!("--kernels / dispatch.kernels: {e}"))
}

fn profile_from(parsed: &Parsed) -> Result<ExperimentProfile, anyhow::Error> {
    let name = parsed.get("profile").unwrap_or("mnist-small");
    let mut profile = ExperimentProfile::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown profile '{name}'"))?;
    let mut doc = condcomp::config::TomlDoc::default();
    if let Some(cfg_path) = parsed.get("config") {
        doc = condcomp::config::TomlDoc::load(Path::new(cfg_path))
            .map_err(|e| anyhow::anyhow!("config: {e}"))?;
    }
    for kv in parsed.get_all("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{kv}'"))?;
        doc.set(k.trim(), v.trim());
    }
    profile.apply_overrides(&doc);
    Ok(profile)
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(sub) = args.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "train" => cmd_train(rest),
        "train-pjrt" => cmd_train_pjrt(rest),
        "serve" => cmd_serve(rest),
        "worker" => cmd_worker(rest),
        "trace" => cmd_trace(rest),
        "calibrate" => cmd_calibrate(rest),
        "experiment" => cmd_experiment(rest),
        "bench" => cmd_bench(rest),
        "bench-flops" => cmd_bench_flops(rest),
        "datagen" => cmd_datagen(rest),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(anyhow::anyhow!("unknown subcommand '{other}'\n\n{}", usage())),
    }
}

fn common_opts(cmd: Command) -> Command {
    cmd.opt(OptSpec::value("profile", "experiment profile (mnist-{tiny,small,paper}, svhn-{tiny,small,paper})").with_default("mnist-small"))
        .opt(OptSpec::value("config", "TOML config file with overrides"))
        .opt(OptSpec::value("set", "override key=value (repeatable)").multi())
        .opt(OptSpec::value("threads", "compute-pool threads (0 = auto)").with_default("0"))
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("train", "train with the native engine"))
        .opt(OptSpec::value("ranks", "estimator ranks per hidden layer, e.g. 50-35-25, or 'control'").with_default("control"))
        .opt(OptSpec::value("bias", "estimator decision bias (§5 extension)").with_default("0"))
        .opt(OptSpec::flag("randomized", "use randomized SVD refresh (§5 extension)"))
        .opt(OptSpec::value("adaptive-energy", "adaptive rank: spectral energy fraction (overrides --ranks)"))
        .opt(OptSpec::flag("quiet", "suppress per-epoch logs"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let profile = profile_from(&parsed)?;
    let threads = apply_threads(&parsed, profile.train.threads)?;
    let ranks = parsed.get_ranks("ranks")?.unwrap_or_default();
    let mut est_cfg = if ranks.is_empty() {
        EstimatorConfig::control()
    } else {
        EstimatorConfig::fixed(&ranks)
    };
    est_cfg.bias = parsed.get_f64("bias")?.unwrap_or(0.0) as f32;
    est_cfg.randomized = parsed.flag("randomized");
    est_cfg.adaptive_energy = parsed.get_f64("adaptive-energy")?;

    eprintln!(
        "training {} ({:?}) estimator={} pool-threads={threads}",
        profile.name,
        profile.net.layers,
        est_cfg.label()
    );
    let mut data = build_dataset(&profile, profile.train.seed ^ 0xDA7A);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let mut trainer = Trainer::new(profile.train.clone());
    trainer.options.quiet = parsed.flag("quiet");

    let test_err = if est_cfg.is_control() {
        trainer.train(&mut net, &mut data, &mut NoGater);
        evaluate_error(&net, &NoGater, &data.test)
    } else {
        let mut gater = SignEstimatorSet::fit(&net, &est_cfg, profile.train.seed ^ 0x5E7);
        trainer.train(&mut net, &mut data, &mut gater);
        gater.refresh(&net);
        evaluate_error(&net, &gater, &data.test)
    };
    println!("final test error: {:.2}%", test_err * 100.0);
    Ok(())
}

fn cmd_train_pjrt(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new(
        "train-pjrt",
        "train through the AOT train_step artifact (L3→L2→L1)",
    ))
    .opt(OptSpec::value("artifacts", "artifacts directory").with_default("artifacts"))
    .opt(OptSpec::flag("quiet", "suppress per-epoch logs"))
    .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let profile = profile_from(&parsed)?;
    let _ = apply_threads(&parsed, profile.train.threads)?;
    let engine = Arc::new(Engine::load(Path::new(parsed.get("artifacts").unwrap()))?);
    eprintln!("pjrt platform: {}", engine.platform());

    let mut data = build_dataset(&profile, profile.train.seed ^ 0xDA7A);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let net = Mlp::init(&profile.net, &mut rng);
    let mut rt = ModelRuntime::from_mlp(engine, &profile.name, &net)?;
    let mut sched = condcomp::coordinator::TrainingScheduler::new(profile.train.clone());
    sched.quiet = parsed.flag("quiet");
    let history = sched.train(&mut rt, &mut data)?;
    if let Some(last) = history.last() {
        println!(
            "final valid error: control {:.2}%  estimator-augmented {:.2}%",
            last.valid_error * 100.0,
            last.valid_error_ae * 100.0
        );
    }
    Ok(())
}

/// Deterministic model prep shared by `serve` (in-process backend) and
/// `worker` (headless replica): train, fit the estimator, apply the kernel
/// allow-list, load/calibrate the dispatch table. The whole flow is seeded,
/// so every process given the same profile/ranks/epochs builds bit-identical
/// weights and serves the same function — which is what makes N-worker
/// serving bit-identical to 1-process serving.
fn prepare_native_backend(
    parsed: &Parsed,
    profile: &ExperimentProfile,
    threads: usize,
) -> anyhow::Result<(Arc<NativeBackend>, Vec<usize>)> {
    eprintln!("preparing model ({})… pool-threads={threads}", profile.name);
    let mut data = build_dataset(profile, profile.train.seed ^ 0xDA7A);
    let mut rng = Pcg32::new(profile.train.seed, 1);
    let mut net = Mlp::init(&profile.net, &mut rng);
    let trainer = Trainer::new(profile.train.clone());
    trainer.train(&mut net, &mut data, &mut NoGater);

    let ranks = match parsed.get_ranks("ranks")? {
        Some(r) if !r.is_empty() => r,
        _ => {
            let paper = ExperimentProfile::mnist_paper();
            let base: Vec<usize> =
                vec![50, 35, 25, 20, 15][..profile.net.num_estimated_layers()].to_vec();
            profile.scale_ranks(&base, &paper)
        }
    };
    // `estimator.quantized` swaps the estimator's low-rank apply onto
    // quantized int8 factors (sign-agreement accuracy, ~4× narrower math).
    let mut est_cfg = EstimatorConfig::fixed(&ranks);
    est_cfg.quantized = profile.estimator.quantized;
    if est_cfg.quantized {
        eprintln!("estimator: int8-quantized low-rank factors (estimator.quantized)");
    }
    let est = SignEstimatorSet::fit(&net, &est_cfg, 7);
    let backend = Arc::new(NativeBackend::new(net, est, 64));
    // Kernel allow-list (`--kernels` / `dispatch.kernels`): restrict the
    // cost router before any calibration, so the columns measured are the
    // columns routed.
    if let Some(ids) = kernel_allowlist(parsed, profile)? {
        backend
            .set_allowed_kernels(&ids)
            .map_err(|e| anyhow::anyhow!("--kernels: {e}"))?;
        eprintln!(
            "dispatch: kernel allow-list [{}]",
            ids.iter().map(|k| k.as_str()).collect::<Vec<_>>().join(", ")
        );
    }
    // The serving roster, each kernel marked with its equivalence tier
    // (bit-exact / tolerance(N) / sign-agree); ids outside the active
    // allow-list (or unregistered, like `pjrt` without the feature) show
    // as unavailable.
    eprintln!("dispatch: kernel roster [{}]", backend.registry().roster());
    // Per-layer dispatch cost tables: persisted machine profile first
    // (recalibrating just the columns it lacks for newly registered
    // kernels), then online calibration, then (per layer, inside the table)
    // the per-kernel defaults with a once-per-process warning.
    let profile_path = parsed
        .get("autotune-profile")
        .map(str::to_string)
        .or_else(|| profile.autotune.profile_path.clone());
    let budget_ms = profile.autotune.budget_ms;
    let table = match &profile_path {
        Some(p) if Path::new(p).exists() => match MachineProfile::load(Path::new(p))
            .and_then(|mp| backend.apply_profile(&mp, p).map(|table| (mp, table)))
        {
            Ok((mp, table)) => {
                eprintln!("dispatch: per-layer thresholds loaded from {p}");
                let missing = mp.missing_kernel_columns(&backend.registry().ids());
                if missing.is_empty() {
                    table
                } else {
                    // The measured columns stay; only the gaps are filled.
                    let names: Vec<&str> = missing.iter().map(|k| k.as_str()).collect();
                    eprintln!(
                        "dispatch: profile {p} has no cost column for [{}]; \
                         recalibrating just those ({budget_ms} ms) — re-run \
                         `condcomp calibrate` to persist them",
                        names.join(", ")
                    );
                    backend.calibrate_kernel_columns(&missing, budget_ms)
                }
            }
            Err(e) => {
                eprintln!(
                    "dispatch: machine profile {p} rejected ({e}); \
                     falling back to online calibration ({budget_ms} ms)"
                );
                backend.calibrate_dispatch(budget_ms)
            }
        },
        Some(p) => {
            eprintln!(
                "dispatch: no machine profile at {p} (run `condcomp calibrate` to create \
                 one); online calibration ({budget_ms} ms)…"
            );
            backend.calibrate_dispatch(budget_ms)
        }
        None => {
            eprintln!(
                "dispatch: autotune.profile_path not set; online calibration ({budget_ms} ms)…"
            );
            backend.calibrate_dispatch(budget_ms)
        }
    };
    for line in table.summary_lines() {
        eprintln!("dispatch: {line}");
    }
    Ok((backend, ranks))
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("serve", "start the serving coordinator"))
        .opt(OptSpec::value("addr", "bind address").with_default("127.0.0.1:7878"))
        .opt(OptSpec::value("ranks", "estimator ranks (default: scaled 50-35-25…)"))
        .opt(OptSpec::value("train-epochs", "epochs to train before serving").with_default("2"))
        .opt(OptSpec::value("max-wait-ms", "dynamic batching window, per shard").with_default("2"))
        .opt(OptSpec::value(
            "worker-addrs",
            "comma-separated worker replica addresses; non-empty = run as a coordinator \
             forwarding batches to `condcomp worker` processes (also: server.worker_addrs)",
        ))
        .opt(OptSpec::value(
            "replicas",
            "minimum workers that must complete the hello handshake at startup (0 = at least one)",
        ))
        .opt(OptSpec::value(
            "shards",
            "batcher shards, each with its own queue + executor (0 = derive from threads)",
        ))
        .opt(OptSpec::value("router", "shard router: round-robin (default) or least-depth"))
        .opt(OptSpec::value(
            "autotune-profile",
            "machine profile from `condcomp calibrate` (default: autotune.profile_path)",
        ))
        .opt(OptSpec::value(
            "kernels",
            "kernel allow-list, comma-separated (dense,dense_packed,dense_simd,dense_i8,masked,\
             masked_simd,masked_i8; default: every bit-exact/tolerance kernel — the sign-agree \
             int8 kernels route only when listed here explicitly)",
        ))
        .opt(OptSpec::flag(
            "trace",
            "enable span tracing + flight recorder (also: server.trace / CONDCOMP_TRACE=1)",
        ))
        .opt(OptSpec::value("trace-ring", "flight-recorder capacity in batch records"))
        .opt(OptSpec::value(
            "max-queue-depth",
            "per-shard queue bound; beyond it requests are shed with an overloaded reply (0 = unbounded)",
        ))
        .opt(OptSpec::value(
            "deadline-ms",
            "per-request deadline; items older than this at drain time get an overloaded reply (0 = none)",
        ))
        .opt(OptSpec::flag(
            "elastic",
            "quality-elastic dispatch: under queue pressure, prefer cheap masked kernels and truncate estimator rank",
        ))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let mut profile = profile_from(&parsed)?;
    profile.train.epochs = parsed.get_usize("train-epochs")?.unwrap_or(2);
    let threads = apply_threads(&parsed, profile.train.threads)?;

    // Worker fleet: CLI `--worker-addrs` wins, then `server.worker_addrs`.
    // Non-empty = run as a coordinator: no local kernels, every batch is
    // forwarded to a fingerprint-verified `condcomp worker` over the wire.
    let worker_addrs: Vec<String> = match parsed.get("worker-addrs") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::to_string)
            .collect(),
        None => profile.server.worker_addrs.clone(),
    };
    let (backend, remote, banner): (Arc<dyn Backend>, Option<Arc<RemoteBackend>>, String) =
        if worker_addrs.is_empty() {
            let (backend, ranks) = prepare_native_backend(&parsed, &profile, threads)?;
            (backend, None, format!("estimator ranks {ranks:?}"))
        } else {
            // The coordinator holds no weights; the expected fingerprint
            // comes from the profile's architecture, and every worker must
            // prove through the hello handshake that it serves that model.
            let expected = condcomp::autotune::model_fingerprint(&profile.net.layers);
            let min_replicas = match parsed.get_usize("replicas")? {
                Some(n) => n,
                None => profile.server.replicas,
            };
            let opts = RemoteOpts {
                connect_timeout: std::time::Duration::from_millis(
                    profile.server.connect_timeout_ms.max(1),
                ),
                retries: profile.server.retry_max,
                backoff: std::time::Duration::from_millis(profile.server.retry_backoff_ms.max(1)),
                health_interval: std::time::Duration::from_millis(
                    profile.server.health_interval_ms.max(1),
                ),
                min_replicas,
                ..RemoteOpts::default()
            };
            eprintln!(
                "coordinator: connecting to {} worker(s) (model {expected})…",
                worker_addrs.len()
            );
            let remote = Arc::new(RemoteBackend::connect(&worker_addrs, &expected, opts)?);
            let banner = format!(
                "coordinator over {} worker replica(s), model {expected}",
                remote.num_replicas()
            );
            (remote.clone() as Arc<dyn Backend>, Some(remote), banner)
        };
    // Sharding knobs: CLI wins, then the profile's `server.*` keys
    // (`--shards 0` / `server.shards = 0` both mean "derive from threads").
    let shards = match parsed.get_usize("shards")? {
        Some(n) => n,
        None => profile.server.shards,
    };
    let router_name =
        parsed.get("router").map(str::to_string).unwrap_or_else(|| profile.server.router.clone());
    let router = condcomp::coordinator::RouterKind::parse(&router_name).ok_or_else(|| {
        anyhow::anyhow!("unknown router '{router_name}' (expected round-robin or least-depth)")
    })?;
    // Observability knobs: `--trace` only ever *enables* (the profile key
    // and `CONDCOMP_TRACE` env can also turn tracing on).
    let trace = parsed.flag("trace") || profile.server.trace;
    let trace_ring = match parsed.get_usize("trace-ring")? {
        Some(n) => n,
        None => profile.server.trace_ring,
    };
    // Overload knobs: CLI wins, then the profile's `server.*` keys.
    let max_queue_depth = match parsed.get_usize("max-queue-depth")? {
        Some(n) => n,
        None => profile.server.max_queue_depth,
    };
    let deadline_ms = match parsed.get_usize("deadline-ms")? {
        Some(n) => n as u64,
        None => profile.server.deadline_ms,
    };
    let deadline = (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms));
    let elastic = parsed.flag("elastic") || profile.server.elastic;
    let server = Server::start(
        backend,
        ServerConfig {
            addr: parsed.get("addr").unwrap().to_string(),
            max_wait: std::time::Duration::from_millis(
                parsed.get_usize("max-wait-ms")?.unwrap_or(2) as u64,
            ),
            shards,
            router,
            threads: parsed.get_usize("threads")?.unwrap_or(0),
            trace,
            trace_ring,
            max_queue_depth,
            deadline,
            elastic,
            ..ServerConfig::default()
        },
    )?;
    // Per-replica metrics flow through the server's registry; the wiring
    // can only happen after start (the server owns the registry).
    if let Some(r) = &remote {
        r.attach_metrics(server.metrics.clone());
    }
    println!(
        "serving on {} ({banner}; {} shard(s), {router} router); Ctrl-C to stop",
        server.local_addr,
        server.num_shards()
    );
    // Park until a client sends the protocol `shutdown` op, then drain the
    // shards and exit cleanly (CI drives the loopback smoke this way).
    while !server.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    eprintln!("shutdown requested; draining shards…");
    server.shutdown();
    Ok(())
}

/// `condcomp worker` — a headless single-shard replica: the same
/// deterministic model prep as `serve` (same profile/seed ⇒ bit-identical
/// weights in every process), served over the TCP protocol for a
/// coordinator to route batches to. Prints the bound address and model
/// fingerprint on stdout so scripts can scrape ephemeral ports.
fn cmd_worker(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("worker", "run a headless serving replica"))
        .opt(OptSpec::value("addr", "bind address (use 127.0.0.1:0 for an ephemeral port)").with_default("127.0.0.1:0"))
        .opt(OptSpec::value("ranks", "estimator ranks (default: scaled 50-35-25…)"))
        .opt(OptSpec::value("train-epochs", "epochs to train before serving").with_default("2"))
        .opt(OptSpec::value("max-wait-ms", "dynamic batching window").with_default("2"))
        .opt(OptSpec::value(
            "autotune-profile",
            "machine profile from `condcomp calibrate` (default: autotune.profile_path)",
        ))
        .opt(OptSpec::value(
            "kernels",
            "kernel allow-list, comma-separated (default: every bit-exact/tolerance kernel; \
             int8 sign-agree kernels opt in by listing them)",
        ))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let mut profile = profile_from(&parsed)?;
    profile.train.epochs = parsed.get_usize("train-epochs")?.unwrap_or(2);
    let threads = apply_threads(&parsed, profile.train.threads)?;
    let (backend, ranks) = prepare_native_backend(&parsed, &profile, threads)?;
    let fingerprint = backend.model_fingerprint().unwrap_or_default();
    let server = Server::start(
        backend,
        ServerConfig {
            addr: parsed.get("addr").unwrap().to_string(),
            max_wait: std::time::Duration::from_millis(
                parsed.get_usize("max-wait-ms")?.unwrap_or(2) as u64,
            ),
            // One shard: the coordinator owns the fleet-level fan-out; the
            // worker's own queue depth is its `queue_pressure` signal.
            shards: 1,
            threads: parsed.get_usize("threads")?.unwrap_or(0),
            ..ServerConfig::default()
        },
    )?;
    // The scrape line: tests and launch scripts parse the port and
    // fingerprint from this exact format.
    println!("worker listening on {} (model {fingerprint}, ranks {ranks:?})", server.local_addr);
    use std::io::Write;
    std::io::stdout().flush().ok();
    while !server.is_stopped() {
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    eprintln!("worker shutdown requested; draining…");
    server.shutdown();
    Ok(())
}

/// `condcomp trace` — dump a running server's flight recorder: the last N
/// batch records (shard, rows, kernels chosen, queue depth at drain,
/// per-span timings) as JSON on stdout. Recording is live only while the
/// server has tracing enabled (`--trace` / `server.trace` /
/// `CONDCOMP_TRACE=1`); without it the dump is an empty ring.
fn cmd_trace(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("trace", "dump a running server's flight recorder")
        .opt(OptSpec::value("addr", "server address").with_default("127.0.0.1:7878"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let addr: std::net::SocketAddr = parsed
        .get("addr")
        .unwrap()
        .parse()
        .map_err(|e| anyhow::anyhow!("--addr: {e}"))?;
    let mut client = condcomp::coordinator::Client::connect(&addr)?;
    let resp = client.trace()?;
    if !resp.ok {
        return Err(anyhow::anyhow!(
            "trace op failed: {}",
            resp.error.unwrap_or_else(|| "unknown error".into())
        ));
    }
    let payload = resp.payload.ok_or_else(|| anyhow::anyhow!("trace response has no payload"))?;
    println!("{payload}");
    Ok(())
}

/// `condcomp calibrate` — measure per-layer dense-vs-masked dispatch
/// thresholds for a profile's architecture on this machine and persist them
/// as a machine-profile JSON. Calibration depends only on the layer shapes
/// (not the weight values), so no training happens here; `serve` loads the
/// file at startup and the measurement is paid once per machine.
fn cmd_calibrate(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new(
        "calibrate",
        "fit per-layer dispatch thresholds; write a machine profile",
    ))
    .opt(OptSpec::value(
        "out",
        "profile output path (default: autotune.profile_path, else condcomp-profile.json)",
    ))
    .opt(OptSpec::value(
        "budget-ms",
        "total calibration wall-clock budget (default: autotune.budget_ms)",
    ))
    .opt(OptSpec::value("batch", "microbenchmark batch rows").with_default("64"))
    .opt(OptSpec::value(
        "kernels",
        "kernel set to fit cost columns for, comma-separated (default: all registered)",
    ))
    .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let profile = profile_from(&parsed)?;
    let threads = apply_threads(&parsed, profile.train.threads)?;
    let budget_ms = parsed
        .get_usize("budget-ms")?
        .map(|v| v as u64)
        .unwrap_or(profile.autotune.budget_ms);
    let out_path = parsed
        .get("out")
        .map(str::to_string)
        .or_else(|| profile.autotune.profile_path.clone())
        .unwrap_or_else(|| "condcomp-profile.json".to_string());

    let mut tuner = Autotuner::with_budget_ms(budget_ms.max(1));
    tuner.batch = parsed.get_usize("batch")?.unwrap_or(64).max(1);
    if let Some(ids) = kernel_allowlist(&parsed, &profile)? {
        // A known-but-unregistered id (e.g. `pjrt` without the feature)
        // would otherwise persist a fabricated default column that later
        // suppresses the missing-column recalibration in a binary that
        // *can* measure it — reject it before anything is written.
        KernelRegistry::builtin()
            .restricted(&ids)
            .map_err(|e| anyhow::anyhow!("--kernels: {e} — cannot calibrate a kernel this \
                 binary has not registered"))?;
        tuner.kernels = ids;
    }
    eprintln!(
        "calibrating {} ({:?}): {} hidden layers on {threads} threads, budget {budget_ms} ms, \
         kernels [{}]",
        profile.name,
        profile.net.layers,
        Autotuner::hidden_shapes(&profile.net.layers).len(),
        tuner.kernels.iter().map(|k| k.as_str()).collect::<Vec<_>>().join(", ")
    );
    let machine = tuner.calibrate_model(&profile.net.layers, condcomp::parallel::global());
    for line in machine.summary_lines() {
        println!("{line}");
    }
    machine.save(Path::new(&out_path))?;
    println!("wrote {out_path}");
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("experiment", "regenerate a paper table/figure"))
        .opt(OptSpec::value("out", "output directory").with_default("results"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") || parsed.positional.is_empty() {
        print!("{}", cmd.help());
        println!("\nexperiments: {}  (or 'all')", condcomp::experiments::ALL_IDS.join(", "));
        return Ok(());
    }
    let id = parsed.positional[0].as_str();
    // Pick a dataset-appropriate default profile for svhn experiments.
    let mut parsed2 = parsed.clone();
    if (id == "fig3" || id == "table2") && parsed.get("profile") == Some("mnist-small") {
        parsed2 = cmd.parse(&{
            let mut v = args.to_vec();
            v.push("--profile".into());
            v.push("svhn-small".into());
            v
        })?;
    }
    let profile = profile_from(&parsed2)?;
    let _ = apply_threads(&parsed, profile.train.threads)?;
    let out = Path::new(parsed.get("out").unwrap()).join(&profile.name);
    condcomp::experiments::run(id, &profile, &out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// `condcomp bench` — the measured dense-vs-masked-vs-parallel sweep
/// (α ∈ {0.05, 0.25, 0.5, 1.0} × threads ∈ {1, N}), written as
/// machine-readable JSON including the fitted per-layer dispatch thresholds
/// for the chosen profile's layer shapes.
fn cmd_bench(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("bench", "dense-vs-masked-vs-parallel wall-clock sweep")
        .opt(OptSpec::value("out", "output JSON path").with_default("BENCH_parallel.json"))
        .opt(OptSpec::value("dim", "square GEMM dimension").with_default("512"))
        .opt(OptSpec::value("batch", "masked-layer batch rows").with_default("64"))
        .opt(OptSpec::value("threads", "compute-pool threads for the parallel arm (0 = auto)").with_default("0"))
        .opt(OptSpec::value("profile", "profile whose layer shapes get per-layer thresholds").with_default("mnist-small"))
        .opt(OptSpec::value(
            "kernels",
            "kernel allow-list for the kernel sweep, comma-separated (default: all registered)",
        ))
        .opt(OptSpec::flag("quick", "shorter measurement budget"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let dim = parsed.get_usize("dim")?.unwrap_or(512);
    let batch = parsed.get_usize("batch")?.unwrap_or(64);
    let threads = match parsed.get_usize("threads")?.unwrap_or(0) {
        0 => condcomp::parallel::default_threads(),
        n => n,
    };
    let cfg = if parsed.flag("quick") {
        condcomp::bench::quick()
    } else {
        condcomp::bench::BenchConfig::default()
    };
    let prof_name = parsed.get("profile").unwrap_or("mnist-small");
    let layer_sizes = ExperimentProfile::by_name(prof_name)
        .ok_or_else(|| anyhow::anyhow!("unknown profile '{prof_name}'"))?
        .net
        .layers;
    let kernels = match parsed.get("kernels") {
        Some(s) => {
            let ids =
                KernelRegistry::parse_allowlist(s).map_err(|e| anyhow::anyhow!("--kernels: {e}"))?;
            // Known-but-unregistered ids (e.g. `pjrt` without the feature)
            // must fail cleanly here, not panic inside the sweep.
            KernelRegistry::builtin()
                .restricted(&ids)
                .map_err(|e| anyhow::anyhow!("--kernels: {e}"))?;
            Some(ids)
        }
        None => None,
    };
    let sweep = condcomp::bench::sweep::run_parallel_sweep(
        &cfg,
        dim,
        batch,
        threads,
        &layer_sizes,
        kernels.as_deref(),
    );
    for line in sweep.report_lines() {
        println!("{line}");
    }
    let out = Path::new(parsed.get("out").unwrap());
    std::fs::write(out, sweep.to_json().to_string())?;
    println!("wrote {}", out.display());
    Ok(())
}

fn cmd_bench_flops(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("bench-flops", "print the §3.4 analytic cost model"))
        .opt(OptSpec::value("alpha", "activation density").with_default("0.1"))
        .opt(OptSpec::value("rank-frac", "rank as a fraction of min(d,h)").with_default("0.05"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let profile = profile_from(&parsed)?;
    let alpha = parsed.get_f64("alpha")?.unwrap_or(0.1);
    let rf = parsed.get_f64("rank-frac")?.unwrap_or(0.05);
    println!("architecture {:?}, α={alpha}, k={rf}·min(d,h)", profile.net.layers);
    println!("{:<8} {:>10} {:>6} {:>14} {:>14} {:>10}", "layer", "shape", "k", "F_nn", "F_ae", "speedup");
    let mut costs = Vec::new();
    for l in 0..profile.net.layers.len() - 2 {
        let (d, h) = (profile.net.layers[l], profile.net.layers[l + 1]);
        let k = ((d.min(h) as f64 * rf) as usize).max(1);
        let c = LayerCost::new(d, h, k, alpha);
        println!(
            "{:<8} {:>10} {:>6} {:>14.0} {:>14.0} {:>9.2}×",
            l,
            format!("{d}×{h}"),
            k,
            c.f_nn(),
            c.f_ae(),
            c.speedup()
        );
        costs.push(c);
    }
    println!("whole network (Eq. 11): {:.2}×", condcomp::cost::network_speedup(&costs));
    for c in &costs {
        if let Some(kmax) = c.max_profitable_rank() {
            println!(
                "  {}×{}: max profitable rank {} @ α={alpha}; max profitable α {:.2} @ k={}",
                c.d, c.h, kmax,
                c.max_profitable_alpha().unwrap_or(0.0),
                c.k
            );
        }
    }
    Ok(())
}

fn cmd_datagen(args: &[String]) -> anyhow::Result<()> {
    let cmd = common_opts(Command::new("datagen", "dump a synthetic corpus to .npy"))
        .opt(OptSpec::value("out", "output directory").with_default("data-out"))
        .opt(OptSpec::flag("help", "show help"));
    let parsed = cmd.parse(args)?;
    if parsed.flag("help") {
        print!("{}", cmd.help());
        return Ok(());
    }
    let profile = profile_from(&parsed)?;
    let out = Path::new(parsed.get("out").unwrap());
    std::fs::create_dir_all(out)?;
    let ds = build_dataset(&profile, profile.train.seed ^ 0xDA7A);
    for (name, split) in [("train", &ds.train), ("valid", &ds.valid), ("test", &ds.test)] {
        condcomp::io::npy::write_mat(&out.join(format!("{name}_x.npy")), &split.x)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let y: Vec<f32> = split.y.iter().map(|&v| v as f32).collect();
        condcomp::io::npy::write_vec(&out.join(format!("{name}_y.npy")), &y)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("{name}: {} examples → {}", split.len(), out.display());
    }
    Ok(())
}
