//! NumPy `.npy` (format version 1.0) reader/writer for `f32` arrays.
//!
//! This is the weight-interchange format between the build-time Python path
//! (`numpy.save`) and the Rust coordinator: checkpoints, estimator factors,
//! and golden test fixtures all travel as little-endian C-order `<f4` arrays
//! of rank 1 or 2.

use crate::linalg::Mat;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Errors from `.npy` parsing.
#[derive(Debug)]
pub enum NpyError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for NpyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NpyError::Io(e) => write!(f, "npy io error: {e}"),
            NpyError::Format(m) => write!(f, "npy format error: {m}"),
        }
    }
}

impl std::error::Error for NpyError {}

impl From<std::io::Error> for NpyError {
    fn from(e: std::io::Error) -> Self {
        NpyError::Io(e)
    }
}

/// An array loaded from `.npy`: shape plus flat C-order data.
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyArray {
    /// Interpret as a 2-D matrix; 1-D arrays become a single row.
    pub fn to_mat(&self) -> Result<Mat, NpyError> {
        match self.shape.len() {
            1 => Ok(Mat::from_vec(1, self.shape[0], self.data.clone())),
            2 => Ok(Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())),
            d => Err(NpyError::Format(format!("expected rank 1 or 2, got rank {d}"))),
        }
    }
}

/// Write a matrix as a 2-D `<f4` `.npy` file.
pub fn write_mat(path: &Path, m: &Mat) -> Result<(), NpyError> {
    write_f32(path, &[m.rows(), m.cols()], m.as_slice())
}

/// Write a 1-D `<f4` `.npy` file.
pub fn write_vec(path: &Path, data: &[f32]) -> Result<(), NpyError> {
    write_f32(path, &[data.len()], data)
}

/// Write an arbitrary-shape little-endian f32 array.
pub fn write_f32(path: &Path, shape: &[usize], data: &[f32]) -> Result<(), NpyError> {
    let count: usize = shape.iter().product();
    if count != data.len() {
        return Err(NpyError::Format(format!(
            "shape {shape:?} implies {count} elements, got {}",
            data.len()
        )));
    }
    let shape_str = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!("({})", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")),
    };
    let mut header =
        format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}");
    // Pad with spaces so that magic+version+len+header is a multiple of 64,
    // terminated by \n (npy spec).
    let unpadded = MAGIC.len() + 2 + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.extend(std::iter::repeat(' ').take(pad));
    header.push('\n');

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?; // version 1.0
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(data.len() * 4);
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read a `.npy` file containing a little-endian f32 (or f64, converted)
/// C-order array.
pub fn read(path: &Path) -> Result<NpyArray, NpyError> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(NpyError::Format("bad magic".into()));
    }
    let mut ver = [0u8; 2];
    f.read_exact(&mut ver)?;
    let header_len = match ver[0] {
        1 => {
            let mut b = [0u8; 2];
            f.read_exact(&mut b)?;
            u16::from_le_bytes(b) as usize
        }
        2 | 3 => {
            let mut b = [0u8; 4];
            f.read_exact(&mut b)?;
            u32::from_le_bytes(b) as usize
        }
        v => return Err(NpyError::Format(format!("unsupported npy version {v}"))),
    };
    let mut header = vec![0u8; header_len];
    f.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header).to_string();

    let descr = dict_value(&header, "descr")
        .ok_or_else(|| NpyError::Format("missing descr".into()))?;
    let fortran = dict_value(&header, "fortran_order")
        .ok_or_else(|| NpyError::Format("missing fortran_order".into()))?;
    if fortran.trim() != "False" {
        return Err(NpyError::Format("fortran_order arrays not supported".into()));
    }
    let shape = parse_shape(&header)?;
    let count: usize = shape.iter().product();

    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    let descr = descr.trim().trim_matches(|c| c == '\'' || c == '"');
    let data = match descr {
        "<f4" => {
            if raw.len() < count * 4 {
                return Err(NpyError::Format("truncated f32 payload".into()));
            }
            raw.chunks_exact(4)
                .take(count)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()
        }
        "<f8" => {
            if raw.len() < count * 8 {
                return Err(NpyError::Format("truncated f64 payload".into()));
            }
            raw.chunks_exact(8)
                .take(count)
                .map(|c| {
                    f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]) as f32
                })
                .collect()
        }
        other => return Err(NpyError::Format(format!("unsupported dtype '{other}'"))),
    };
    Ok(NpyArray { shape, data })
}

/// Read directly into a `Mat`.
pub fn read_mat(path: &Path) -> Result<Mat, NpyError> {
    read(path)?.to_mat()
}

/// Extract the raw text of a python-dict value for `key` from the header.
fn dict_value<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let kq = format!("'{key}'");
    let at = header.find(&kq)?;
    let rest = &header[at + kq.len()..];
    let colon = rest.find(':')?;
    let rest = &rest[colon + 1..];
    // Value ends at the next top-level comma or closing brace.
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => depth = depth.saturating_sub(1),
            ',' | '}' if depth == 0 => return Some(rest[..i].trim()),
            _ => {}
        }
    }
    Some(rest.trim())
}

fn parse_shape(header: &str) -> Result<Vec<usize>, NpyError> {
    let raw = dict_value(header, "shape")
        .ok_or_else(|| NpyError::Format("missing shape".into()))?;
    let inner = raw
        .trim()
        .strip_prefix('(')
        .and_then(|s| s.strip_suffix(')'))
        .ok_or_else(|| NpyError::Format(format!("bad shape '{raw}'")))?;
    inner
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|_| NpyError::Format(format!("bad dim '{s}'"))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("condcomp-npy-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn mat_roundtrip() {
        property("npy mat roundtrip", 16, |rng| {
            let r = rng.index(8) + 1;
            let c = rng.index(8) + 1;
            let m = Mat::randn(r, c, 1.0, rng);
            let path = tmpfile(&format!("m_{r}_{c}.npy"));
            write_mat(&path, &m).unwrap();
            let back = read_mat(&path).unwrap();
            assert_eq!(back, m);
        });
    }

    #[test]
    fn vec_roundtrip() {
        let path = tmpfile("v.npy");
        let v = vec![1.0f32, -2.5, 3.25];
        write_vec(&path, &v).unwrap();
        let arr = read(&path).unwrap();
        assert_eq!(arr.shape, vec![3]);
        assert_eq!(arr.data, v);
        assert_eq!(arr.to_mat().unwrap().shape(), (1, 3));
    }

    #[test]
    fn header_is_64_byte_aligned() {
        let path = tmpfile("aligned.npy");
        write_vec(&path, &[0.0; 7]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Total prefix before data must be divisible by 64.
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("bad.npy");
        std::fs::write(&path, b"not-an-npy-file-at-all").unwrap();
        assert!(matches!(read(&path), Err(NpyError::Format(_))));
    }

    #[test]
    fn shape_data_mismatch_rejected() {
        let path = tmpfile("mismatch.npy");
        let err = write_f32(&path, &[2, 3], &[0.0; 5]);
        assert!(err.is_err());
    }

    #[test]
    fn numpy_compat_header_parses() {
        // A header exactly as numpy 2.x emits it (with trailing spaces + \n).
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(3, 2, 1.0, &mut rng);
        let path = tmpfile("npcompat.npy");
        write_mat(&path, &m).unwrap();
        let text = std::fs::read(&path).unwrap();
        let hlen = u16::from_le_bytes([text[8], text[9]]) as usize;
        let header = String::from_utf8_lossy(&text[10..10 + hlen]).to_string();
        assert!(header.contains("'descr': '<f4'"));
        assert!(header.contains("'shape': (3, 2)"));
    }
}
