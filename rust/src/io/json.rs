//! A minimal JSON parser and emitter.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) — enough for the serving protocol, the artifact
//! manifest, and experiment reports, without a serde dependency (offline
//! environment). Numbers are stored as `f64`; object key order is preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Objects use a BTreeMap for deterministic emission order.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // --- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]` convenience; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers from an `f32` slice.
    pub fn num_arr(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Extract an `f32` vector from an array of numbers.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("invalid \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulL").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn roundtrip_random_structures() {
        fn arb_json(rng: &mut crate::util::Pcg32, depth: usize) -> Json {
            match if depth == 0 { rng.index(4) } else { rng.index(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.bernoulli(0.5)),
                2 => Json::Num((rng.uniform_in(-1000.0, 1000.0) as f64 * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}\"\\\n{}", rng.index(100), rng.index(10))),
                4 => Json::Arr((0..rng.index(4)).map(|_| arb_json(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.index(4))
                        .map(|i| (format!("k{i}"), arb_json(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        property("json roundtrip", 64, |rng| {
            let v = arb_json(rng, 3);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap_or_else(|e| panic!("{e} in {text}"));
            assert_eq!(back, v, "text: {text}");
        });
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn f32_vec_helpers() {
        let v = Json::num_arr(&[1.0, 2.5, -3.0]);
        assert_eq!(v.to_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(4.25).to_string(), "4.25");
    }
}
