//! Serialization: minimal JSON (protocol + manifests) and NumPy `.npy`
//! (weight interchange with the build-time Python path).

pub mod json;
pub mod npy;

pub use json::Json;
