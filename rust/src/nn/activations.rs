//! Activation functions and the softmax/NLL output head.

use crate::linalg::Mat;

/// Rectified linear: `max(0, x)` (paper Eq. 3).
#[inline]
pub fn relu(x: f32) -> f32 {
    if x > 0.0 { x } else { 0.0 }
}

/// Apply ReLU in place.
pub fn relu_inplace(m: &mut Mat) {
    m.map_inplace(relu);
}

/// Derivative mask of ReLU w.r.t. its *output* (1 where output > 0).
#[inline]
pub fn relu_grad_from_output(y: f32) -> f32 {
    if y > 0.0 { 1.0 } else { 0.0 }
}

/// Row-wise softmax, numerically stabilized by max subtraction.
pub fn softmax_rows(logits: &Mat) -> Mat {
    let (n, k) = logits.shape();
    let mut out = Mat::zeros(n, k);
    for i in 0..n {
        let row = logits.row(i);
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        let orow = out.row_mut(i);
        for j in 0..k {
            let e = (row[j] - m).exp();
            orow[j] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for v in orow {
            *v *= inv;
        }
    }
    out
}

/// Mean negative log-likelihood of the true classes under row-softmax
/// probabilities. `probs` must already be softmaxed.
pub fn nll_loss(probs: &Mat, labels: &[usize]) -> f32 {
    assert_eq!(probs.rows(), labels.len());
    let mut total = 0.0f64;
    for (i, &y) in labels.iter().enumerate() {
        total -= (probs[(i, y)].max(1e-12) as f64).ln();
    }
    (total / labels.len() as f64) as f32
}

/// Gradient of mean NLL w.r.t. the logits: `(softmax − one_hot) / n`.
pub fn nll_grad(probs: &Mat, labels: &[usize]) -> Mat {
    let (n, k) = probs.shape();
    assert_eq!(n, labels.len());
    let invn = 1.0 / n as f32;
    let mut g = Mat::zeros(n, k);
    for i in 0..n {
        let prow = probs.row(i);
        let grow = g.row_mut(i);
        for j in 0..k {
            grow[j] = prow[j] * invn;
        }
        grow[labels[i]] -= invn;
    }
    g
}

/// Row-wise argmax (predicted class).
pub fn argmax_rows(m: &Mat) -> Vec<usize> {
    (0..m.rows())
        .map(|i| {
            let row = m.row(i);
            let mut best = 0;
            for j in 1..row.len() {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

/// Classification error rate in `[0, 1]`.
pub fn error_rate(predicted: &[usize], labels: &[usize]) -> f32 {
    assert_eq!(predicted.len(), labels.len());
    if labels.is_empty() {
        return 0.0;
    }
    let wrong = predicted.iter().zip(labels).filter(|(p, y)| p != y).count();
    wrong as f32 / labels.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::property;
    use crate::util::Pcg32;

    #[test]
    fn relu_behaviour() {
        assert_eq!(relu(-2.0), 0.0);
        assert_eq!(relu(3.0), 3.0);
        assert_eq!(relu_grad_from_output(0.0), 0.0);
        assert_eq!(relu_grad_from_output(0.1), 1.0);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        property("softmax normalizes", 16, |rng| {
            let n = rng.index(6) + 1;
            let k = rng.index(6) + 2;
            let logits = Mat::randn(n, k, 3.0, rng);
            let p = softmax_rows(&logits);
            for i in 0..n {
                let s: f32 = p.row(i).iter().sum();
                assert!((s - 1.0).abs() < 1e-5);
                assert!(p.row(i).iter().all(|&v| v >= 0.0));
            }
        });
    }

    #[test]
    fn softmax_is_shift_invariant() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![101.0, 102.0, 103.0]);
        assert!(softmax_rows(&a).max_abs_diff(&softmax_rows(&b)) < 1e-6);
    }

    #[test]
    fn nll_of_perfect_prediction_is_zero() {
        let probs = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert!(nll_loss(&probs, &[0, 1]) < 1e-6);
    }

    #[test]
    fn nll_grad_matches_finite_difference() {
        let mut rng = Pcg32::seeded(3);
        let logits = Mat::randn(3, 4, 1.0, &mut rng);
        let labels = vec![1, 3, 0];
        let g = nll_grad(&softmax_rows(&logits), &labels);
        let eps = 1e-3f32;
        for r in 0..3 {
            for c in 0..4 {
                let mut plus = logits.clone();
                plus[(r, c)] += eps;
                let mut minus = logits.clone();
                minus[(r, c)] -= eps;
                let num = (nll_loss(&softmax_rows(&plus), &labels)
                    - nll_loss(&softmax_rows(&minus), &labels))
                    / (2.0 * eps);
                assert!(
                    (num - g[(r, c)]).abs() < 1e-3,
                    "grad mismatch at ({r},{c}): numeric {num} vs analytic {}",
                    g[(r, c)]
                );
            }
        }
    }

    #[test]
    fn argmax_and_error_rate() {
        let m = Mat::from_vec(2, 3, vec![0.1, 0.7, 0.2, 0.9, 0.05, 0.05]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
        assert_eq!(error_rate(&[1, 0], &[1, 1]), 0.5);
        assert_eq!(error_rate(&[], &[]), 0.0);
    }
}
