//! The training loop: epochs, minibatches, schedules, metrics.

use super::activations::{error_rate, nll_grad, nll_loss, softmax_rows};
use super::mlp::{ActivationGater, Mlp, NoGater};
use super::optimizer::SgdMomentum;
use crate::config::TrainConfig;
use crate::data::{Batcher, Dataset, Split};
use crate::util::{Pcg32, Timer};

/// An [`ActivationGater`] that can also refresh itself from the live weights
/// — the trainer calls `maybe_refresh` before every minibatch, and the
/// implementation decides whether its policy (once per epoch, every N
/// batches, …) fires. The control path uses [`NoGater`].
pub trait TrainGater: ActivationGater {
    fn maybe_refresh(&mut self, net: &Mlp, epoch: usize, batch_index: usize);
}

impl TrainGater for NoGater {
    fn maybe_refresh(&mut self, _net: &Mlp, _epoch: usize, _batch_index: usize) {}
}

/// Per-epoch record — one row of Figures 3/5.
#[derive(Clone, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f32,
    pub train_error: f32,
    pub valid_error: f32,
    /// Mean hidden activation density α (§3.4) measured on training batches.
    pub mean_density: f32,
    pub lr: f32,
    pub momentum: f32,
    pub seconds: f64,
}

/// Knobs that are about the loop, not the optimization.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub quiet: bool,
    /// Cap on examples used per validation pass (0 = all).
    pub max_valid: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { quiet: true, max_valid: 0 }
    }
}

/// Orchestrates training of one network on one dataset.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub options: TrainOptions,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        Trainer { cfg, options: TrainOptions::default() }
    }

    /// Run the full schedule, returning one [`EpochStats`] per epoch.
    /// The gater participates in both training forward passes and validation
    /// (the paper evaluates estimator-augmented nets end to end).
    pub fn train(
        &self,
        net: &mut Mlp,
        data: &mut Dataset,
        gater: &mut dyn TrainGater,
    ) -> Vec<EpochStats> {
        // Size the shared compute pool from the config knob. Lower
        // precedence than an explicit CLI/env request (if_unset), and a
        // no-op once the pool exists; the kernels are thread-count-invariant
        // so this only affects wall-clock, never the training trajectory.
        if self.cfg.threads > 0 {
            crate::parallel::configure_global_if_unset(self.cfg.threads);
        }
        let mut rng = Pcg32::new(self.cfg.seed, 7);
        let mut opt = SgdMomentum::new(net, self.cfg.clone());
        let mut batcher = Batcher::new(data.train.len(), self.cfg.batch_size);
        let mut history = Vec::with_capacity(self.cfg.epochs);

        for epoch in 0..self.cfg.epochs {
            let mut timer = Timer::start();
            batcher.shuffle(&mut rng);
            let mut loss_sum = 0.0f64;
            let mut err_sum = 0.0f64;
            let mut density_sum = 0.0f64;
            let mut batches = 0usize;

            for batch in batcher.epoch(&data.train) {
                gater.maybe_refresh(net, epoch, batch.index);
                let mut drop_rng = rng.split();
                let trace = net.forward(
                    &batch.x,
                    gater,
                    if self.cfg.dropout_p > 0.0 {
                        Some((self.cfg.dropout_p, &mut drop_rng))
                    } else {
                        None
                    },
                );
                let probs = softmax_rows(&trace.logits);
                let loss = nll_loss(&probs, &batch.y);
                let dlogits = nll_grad(&probs, &batch.y);
                let (dws, dbs) = net.backward(&trace, &dlogits, self.cfg.l1_activation);
                opt.step(net, &dws, &dbs);

                loss_sum += loss as f64;
                err_sum += error_rate(
                    &super::activations::argmax_rows(&trace.logits),
                    &batch.y,
                ) as f64;
                density_sum += Mlp::mean_density(&trace) as f64;
                batches += 1;
            }

            let valid_error = evaluate_error_capped(net, gater, &data.valid, self.options.max_valid);
            let stats = EpochStats {
                epoch,
                train_loss: (loss_sum / batches as f64) as f32,
                train_error: (err_sum / batches as f64) as f32,
                valid_error,
                mean_density: (density_sum / batches as f64) as f32,
                lr: opt.learning_rate(),
                momentum: opt.momentum(),
                seconds: timer.lap_s(),
            };
            if !self.options.quiet {
                eprintln!(
                    "epoch {:>3}  loss {:.4}  train-err {:.2}%  valid-err {:.2}%  α {:.3}  lr {:.4}  ({:.1}s)",
                    stats.epoch,
                    stats.train_loss,
                    stats.train_error * 100.0,
                    stats.valid_error * 100.0,
                    stats.mean_density,
                    stats.lr,
                    stats.seconds,
                );
            }
            history.push(stats);
            opt.next_epoch();
        }
        history
    }
}

/// Classification error of `net` (+gater) on a split, evaluated in chunks so
/// large splits do not blow up peak memory.
pub fn evaluate_error(net: &Mlp, gater: &dyn ActivationGater, split: &Split) -> f32 {
    evaluate_error_capped(net, gater, split, 0)
}

fn evaluate_error_capped(
    net: &Mlp,
    gater: &dyn ActivationGater,
    split: &Split,
    cap: usize,
) -> f32 {
    let n = if cap == 0 { split.len() } else { split.len().min(cap) };
    if n == 0 {
        return 0.0;
    }
    let chunk = 512;
    let mut wrong = 0usize;
    let mut at = 0usize;
    while at < n {
        let len = chunk.min(n - at);
        let x = split.x.rows_slice(at, len);
        let pred = net.predict(&x, gater);
        wrong += pred
            .iter()
            .zip(&split.y[at..at + len])
            .filter(|(p, y)| p != y)
            .count();
        at += len;
    }
    wrong as f32 / n as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentProfile;
    use crate::data::synth::build_dataset;

    /// End-to-end smoke: a small net on the synthetic corpus must beat chance
    /// by a wide margin within a few epochs. This is the crate's core
    /// "training works" signal.
    #[test]
    fn trains_above_chance_on_synthetic_digits() {
        let mut profile = ExperimentProfile::mnist_tiny();
        profile.net.layers = vec![784, 48, 32, 10];
        profile.n_train = 600;
        profile.n_valid = 150;
        profile.n_test = 150;
        profile.train.epochs = 4;
        profile.train.batch_size = 50;
        let mut data = build_dataset(&profile, 11);
        let mut rng = Pcg32::new(profile.train.seed, 1);
        let mut net = Mlp::init(&profile.net, &mut rng);
        let trainer = Trainer::new(profile.train.clone());
        let history = trainer.train(&mut net, &mut data, &mut NoGater);
        assert_eq!(history.len(), 4);
        let last = history.last().unwrap();
        assert!(
            last.valid_error < 0.5,
            "validation error {:.3} should beat chance (0.9) clearly",
            last.valid_error
        );
        // Loss must broadly decrease.
        assert!(last.train_loss < history[0].train_loss);
        let test_err = evaluate_error(&net, &NoGater, &data.test);
        assert!(test_err < 0.6, "test error {test_err}");
    }

    #[test]
    fn history_records_schedules() {
        let mut profile = ExperimentProfile::mnist_tiny();
        profile.net.layers = vec![784, 16, 12, 10];
        profile.n_train = 100;
        profile.n_valid = 40;
        profile.n_test = 40;
        profile.train.epochs = 3;
        let mut data = build_dataset(&profile, 3);
        let mut rng = Pcg32::new(1, 1);
        let mut net = Mlp::init(&profile.net, &mut rng);
        let trainer = Trainer::new(profile.train.clone());
        let history = trainer.train(&mut net, &mut data, &mut NoGater);
        assert!(history[1].lr < history[0].lr, "lr must decay");
        assert!(history[1].momentum >= history[0].momentum, "momentum must grow");
        assert!(history.iter().all(|s| s.seconds >= 0.0));
    }

    #[test]
    fn training_is_reproducible() {
        let mut profile = ExperimentProfile::mnist_tiny();
        profile.net.layers = vec![784, 12, 10];
        profile.n_train = 80;
        profile.n_valid = 20;
        profile.n_test = 20;
        profile.train.epochs = 2;
        let run = || {
            let mut data = build_dataset(&profile, 5);
            let mut rng = Pcg32::new(profile.train.seed, 1);
            let mut net = Mlp::init(&profile.net, &mut rng);
            Trainer::new(profile.train.clone()).train(&mut net, &mut data, &mut NoGater);
            net.weights[0].as_slice().to_vec()
        };
        assert_eq!(run(), run());
    }
}
