//! The multilayer perceptron: parameters, forward (with optional activation
//! gating and dropout), and backpropagation.

use super::activations::{argmax_rows, relu_inplace, softmax_rows};
use crate::condcomp::KernelId;
use crate::config::NetConfig;
use crate::exec::ExecCtx;
use crate::linalg::{matmul_auto, matmul_into_ctx, matmul_into_packed_ctx, Mat};
use crate::util::Pcg32;

/// Supplies the paper's `S_l` mask (Eq. 5) for a hidden layer, given that
/// layer's *input* activations `a_l`. Returning `None` means "no gating"
/// (compute the layer densely).
pub trait ActivationGater {
    fn gate(&self, layer: usize, input: &Mat) -> Option<Mat>;
}

/// The trivial gater: never gates (control network).
pub struct NoGater;

impl ActivationGater for NoGater {
    fn gate(&self, _layer: usize, _input: &Mat) -> Option<Mat> {
        None
    }
}

/// Everything the backward pass needs from a forward pass.
pub struct ForwardTrace {
    /// Per-layer inputs: `inputs[0]` is the batch, `inputs[l]` the (gated,
    /// dropped-out) activation entering weight layer `l`.
    pub inputs: Vec<Mat>,
    /// Post-ReLU, post-gate, pre-dropout activations of the hidden layers
    /// (used for the ℓ1 penalty term and sparsity metrics).
    pub hidden: Vec<Mat>,
    /// Dropout masks actually applied (empty when not training).
    pub dropout_masks: Vec<Mat>,
    /// Final logits.
    pub logits: Mat,
}

/// A fully-connected ReLU network with softmax output.
#[derive(Clone, Debug)]
pub struct Mlp {
    /// `weights[l]` is `layers[l] × layers[l+1]`.
    pub weights: Vec<Mat>,
    /// `biases[l]` has `layers[l+1]` entries.
    pub biases: Vec<Vec<f32>>,
}

impl Mlp {
    /// Initialize per the paper (§3.5): `w ~ N(0, σ²)`, biases = `bias_init`
    /// ("set to 1 in order to encourage the neurons to operate in their
    /// non-saturated region").
    pub fn init(cfg: &NetConfig, rng: &mut Pcg32) -> Mlp {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..cfg.num_weight_layers() {
            weights.push(Mat::randn(cfg.layers[l], cfg.layers[l + 1], cfg.weight_sigma, rng));
            biases.push(vec![cfg.bias_init; cfg.layers[l + 1]]);
        }
        Mlp { weights, biases }
    }

    /// Number of weight layers.
    pub fn depth(&self) -> usize {
        self.weights.len()
    }

    /// Layer widths, input first.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut v = vec![self.weights[0].rows()];
        v.extend(self.weights.iter().map(|w| w.cols()));
        v
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.weights.iter().map(|w| w.rows() * w.cols()).sum::<usize>()
            + self.biases.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Forward pass.
    ///
    /// * `gater` — supplies the estimator mask per hidden layer (Eq. 5);
    ///   `NoGater` for the control path.
    /// * `dropout` — `Some((p, rng))` enables inverted dropout on hidden
    ///   activations (train time); `None` disables it (inference — inverted
    ///   dropout needs no weight rescaling at test time, numerically
    ///   equivalent to the paper's halve-at-test convention in expectation).
    pub fn forward(
        &self,
        x: &Mat,
        gater: &dyn ActivationGater,
        mut dropout: Option<(f32, &mut Pcg32)>,
    ) -> ForwardTrace {
        let depth = self.depth();
        let mut inputs = Vec::with_capacity(depth + 1);
        let mut hidden = Vec::with_capacity(depth.saturating_sub(1));
        let mut dropout_masks = Vec::new();
        inputs.push(x.clone());

        let mut current = x.clone();
        for l in 0..depth - 1 {
            // Ask for the gate BEFORE computing the layer — that is the
            // paper's contract (the estimator sees a_l only).
            let gate = gater.gate(l, &current);
            // Dense layer products ride the shared worker pool above the
            // size threshold; matmul_auto is bit-identical to the serial
            // kernel, so traces stay reproducible for any thread count.
            let mut z = matmul_auto(&current, &self.weights[l]);
            add_bias(&mut z, &self.biases[l]);
            relu_inplace(&mut z);
            if let Some(mask) = gate {
                debug_assert_eq!(mask.shape(), z.shape());
                z = z.zip(&mask, |a, m| a * m);
            }
            hidden.push(z.clone());
            if let Some((p, ref mut rng)) = dropout {
                let keep = 1.0 - p;
                let inv = 1.0 / keep;
                let mask = Mat::from_fn(z.rows(), z.cols(), |_, _| {
                    if rng.bernoulli(keep) { inv } else { 0.0 }
                });
                z = z.zip(&mask, |a, m| a * m);
                dropout_masks.push(mask);
            }
            inputs.push(z.clone());
            current = z;
        }
        let mut logits = matmul_auto(&current, &self.weights[depth - 1]);
        add_bias(&mut logits, &self.biases[depth - 1]);
        ForwardTrace { inputs, hidden, dropout_masks, logits }
    }

    /// Inference logits (no dropout).
    pub fn logits(&self, x: &Mat, gater: &dyn ActivationGater) -> Mat {
        self.forward(x, gater, None).logits
    }

    /// Dense inference forward through an execution context — the serving
    /// control path. Bit-identical to `logits(x, &NoGater)`: same GEMM
    /// accumulation order (the parallel kernel ≡ the serial oracle for any
    /// lease width, and the packed kernel ≡ the plain one bitwise), same
    /// bias-then-ReLU per hidden layer; activation buffers come from (and
    /// return to) the ctx's arena, so nothing is allocated per batch after
    /// warmup. The returned logits own an arena buffer — serving callers
    /// hand it back via [`ExecCtx::put_buf`].
    ///
    /// When the ctx pins a dispatch [`crate::condcomp::PolicyTable`] whose
    /// `dense_packed` column beats `dense` for a layer, that layer's GEMM
    /// runs the A-panel-packing variant — a routing decision that can never
    /// change the output bits, only the wall-clock.
    pub fn logits_ctx(&self, x: &Mat, ctx: &mut ExecCtx<'_>) -> Mat {
        let depth = self.depth();
        let mut a = x.clone();
        for l in 0..depth {
            let (n, h) = (a.rows(), self.weights[l].cols());
            let mut out = Mat::from_vec(n, h, ctx.take_buf(n * h));
            let packed = ctx
                .policy()
                .map_or(false, |t| t.dense_kernel_for(l) == KernelId::DENSE_PACKED);
            if packed {
                matmul_into_packed_ctx(&a, &self.weights[l], &mut out, ctx);
            } else {
                matmul_into_ctx(&a, &self.weights[l], &mut out, ctx);
            }
            add_bias(&mut out, &self.biases[l]);
            if l < depth - 1 {
                relu_inplace(&mut out);
            }
            let prev = std::mem::replace(&mut a, out);
            if l > 0 {
                // `prev` owns an arena buffer (the layer-0 input is the
                // caller's batch).
                ctx.put_buf(prev.into_vec());
            }
        }
        a
    }

    /// Predicted classes.
    pub fn predict(&self, x: &Mat, gater: &dyn ActivationGater) -> Vec<usize> {
        argmax_rows(&self.logits(x, gater))
    }

    /// Class probabilities.
    pub fn predict_proba(&self, x: &Mat, gater: &dyn ActivationGater) -> Mat {
        softmax_rows(&self.logits(x, gater))
    }

    /// Backpropagation from a logits-gradient. Returns `(dW, db)` per layer.
    ///
    /// `l1_activation` adds the subgradient of `λ·Σ‖a_l‖₁` (Eq. 7) at each
    /// *live* hidden unit (a_l ≥ 0 after ReLU, so the subgradient is +λ on
    /// active units, 0 on inactive ones).
    pub fn backward(
        &self,
        trace: &ForwardTrace,
        dlogits: &Mat,
        l1_activation: f32,
    ) -> (Vec<Mat>, Vec<Vec<f32>>) {
        let depth = self.depth();
        let mut dws = vec![Mat::zeros(0, 0); depth];
        let mut dbs = vec![Vec::new(); depth];
        let mut delta = dlogits.clone(); // grad wrt pre-activation of layer l

        for l in (0..depth).rev() {
            // Parameter grads for this layer (pool-parallel above threshold).
            dws[l] = matmul_auto(&trace.inputs[l].transpose(), &delta);
            dbs[l] = col_sums(&delta);
            if l == 0 {
                break;
            }
            // Grad wrt this layer's input = delta · Wᵀ …
            let mut dinput = matmul_auto(&delta, &self.weights[l].transpose());
            // … through dropout …
            if !trace.dropout_masks.is_empty() {
                dinput = dinput.zip(&trace.dropout_masks[l - 1], |g, m| g * m);
            }
            // … plus the ℓ1 activation penalty on the (pre-dropout) hidden
            // activation, then through the ReLU/gate zero pattern.
            let h = &trace.hidden[l - 1];
            delta = Mat::from_fn(dinput.rows(), dinput.cols(), |i, j| {
                let live = h[(i, j)] > 0.0;
                if live { dinput[(i, j)] + l1_activation } else { 0.0 }
            });
        }
        (dws, dbs)
    }

    /// Mean activation density over the hidden layers of a forward trace
    /// (the paper's sparsity coefficient α, §3.4).
    pub fn mean_density(trace: &ForwardTrace) -> f32 {
        if trace.hidden.is_empty() {
            return 0.0;
        }
        trace.hidden.iter().map(|h| h.density()).sum::<f32>() / trace.hidden.len() as f32
    }
}

/// Add a bias row-vector to every row.
pub fn add_bias(m: &mut Mat, bias: &[f32]) {
    assert_eq!(m.cols(), bias.len());
    for i in 0..m.rows() {
        let row = m.row_mut(i);
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums (bias gradient).
fn col_sums(m: &Mat) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols()];
    for i in 0..m.rows() {
        for (o, &v) in out.iter_mut().zip(m.row(i)) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activations::{nll_grad, nll_loss, softmax_rows};
    use crate::util::Pcg32;

    fn tiny_cfg() -> NetConfig {
        NetConfig { layers: vec![5, 7, 6, 3], weight_sigma: 0.5, bias_init: 0.1 }
    }

    #[test]
    fn shapes_and_param_count() {
        let mut rng = Pcg32::seeded(1);
        let net = Mlp::init(&tiny_cfg(), &mut rng);
        assert_eq!(net.depth(), 3);
        assert_eq!(net.layer_sizes(), vec![5, 7, 6, 3]);
        assert_eq!(net.num_params(), 5 * 7 + 7 * 6 + 6 * 3 + 7 + 6 + 3);
        let x = Mat::randn(4, 5, 1.0, &mut rng);
        let t = net.forward(&x, &NoGater, None);
        assert_eq!(t.logits.shape(), (4, 3));
        assert_eq!(t.hidden.len(), 2);
        assert_eq!(t.inputs.len(), 3);
    }

    #[test]
    fn forward_is_deterministic_without_dropout() {
        let mut rng = Pcg32::seeded(2);
        let net = Mlp::init(&tiny_cfg(), &mut rng);
        let x = Mat::randn(3, 5, 1.0, &mut rng);
        let a = net.logits(&x, &NoGater);
        let b = net.logits(&x, &NoGater);
        assert_eq!(a, b);
    }

    /// The ctx forward is the ungated forward: bit-identical for any lease
    /// width, cold or warm arena.
    #[test]
    fn logits_ctx_is_bit_identical_to_logits() {
        let mut rng = Pcg32::seeded(21);
        let net = Mlp::init(&tiny_cfg(), &mut rng);
        let x = Mat::randn(6, 5, 1.0, &mut rng);
        let want = net.logits(&x, &NoGater);
        let pool = crate::parallel::ThreadPool::new(3);
        for k in [0usize, 1, 3] {
            let mut ctx = crate::exec::ExecCtx::over(pool.lease(k));
            for round in 0..2 {
                let got = net.logits_ctx(&x, &mut ctx);
                assert_eq!(got.as_slice(), want.as_slice(), "lease {k} round {round}");
                let logits_buf = got.into_vec();
                ctx.put_buf(logits_buf);
            }
        }
        // A pinned policy preferring the packed GEMM routes every layer
        // through it — and cannot change a single output bit.
        use crate::condcomp::{DispatchPolicy, KernelId, PolicyTable};
        let packed_policy = DispatchPolicy::from_columns(vec![
            (KernelId::DENSE, 1.0),
            (KernelId::DENSE_PACKED, 0.5),
        ]);
        assert_eq!(packed_policy.preferred_dense(), KernelId::DENSE_PACKED);
        let table = PolicyTable::uniform(packed_policy, net.depth() - 1);
        let mut ctx = crate::exec::ExecCtx::over(pool.lease(3)).with_policy(table);
        let got = net.logits_ctx(&x, &mut ctx);
        assert_eq!(got.as_slice(), want.as_slice(), "packed routing changed bits");
    }

    #[test]
    fn dropout_zeroes_and_scales() {
        let mut rng = Pcg32::seeded(3);
        let net = Mlp::init(&tiny_cfg(), &mut rng);
        let x = Mat::randn(64, 5, 1.0, &mut rng);
        let mut drop_rng = Pcg32::seeded(99);
        let t = net.forward(&x, &NoGater, Some((0.5, &mut drop_rng)));
        assert_eq!(t.dropout_masks.len(), 2);
        let zeros = t.dropout_masks[0]
            .as_slice()
            .iter()
            .filter(|&&m| m == 0.0)
            .count() as f32;
        let total = t.dropout_masks[0].as_slice().len() as f32;
        let rate = zeros / total;
        assert!((rate - 0.5).abs() < 0.08, "dropout rate {rate}");
        // Non-zero mask entries are 1/keep = 2.0 (inverted dropout).
        assert!(t.dropout_masks[0].as_slice().iter().all(|&m| m == 0.0 || m == 2.0));
    }

    /// Full-network finite-difference gradient check, including the ℓ1
    /// activation penalty — the core correctness test for the trainer.
    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg32::seeded(7);
        let cfg = NetConfig { layers: vec![4, 6, 5, 3], weight_sigma: 0.6, bias_init: 0.05 };
        let mut net = Mlp::init(&cfg, &mut rng);
        let x = Mat::randn(5, 4, 1.0, &mut rng);
        let labels = vec![0, 2, 1, 2, 0];
        let l1 = 1e-3f32;

        let loss_of = |net: &Mlp| {
            let t = net.forward(&x, &NoGater, None);
            let base = nll_loss(&softmax_rows(&t.logits), &labels);
            let penalty: f32 = t.hidden.iter().map(|h| h.l1_norm()).sum::<f32>() * l1;
            base + penalty
        };

        let t = net.forward(&x, &NoGater, None);
        let dlogits = nll_grad(&softmax_rows(&t.logits), &labels);
        let (dws, dbs) = net.backward(&t, &dlogits, l1);

        let eps = 1e-2f32;
        // Sample a few coordinates of each parameter tensor.
        let mut checked = 0;
        for l in 0..net.depth() {
            let (rows, cols) = net.weights[l].shape();
            for _ in 0..6 {
                let (r, c) = (rng.index(rows), rng.index(cols));
                let orig = net.weights[l][(r, c)];
                net.weights[l][(r, c)] = orig + eps;
                let lp = loss_of(&net);
                net.weights[l][(r, c)] = orig - eps;
                let lm = loss_of(&net);
                net.weights[l][(r, c)] = orig;
                let num = (lp - lm) / (2.0 * eps);
                let ana = dws[l][(r, c)];
                assert!(
                    (num - ana).abs() < 2e-2 + 0.05 * num.abs().max(ana.abs()),
                    "dW[{l}][{r},{c}] numeric {num} vs analytic {ana}"
                );
                checked += 1;
            }
            let b = rng.index(net.biases[l].len());
            let orig = net.biases[l][b];
            net.biases[l][b] = orig + eps;
            let lp = loss_of(&net);
            net.biases[l][b] = orig - eps;
            let lm = loss_of(&net);
            net.biases[l][b] = orig;
            let num = (lp - lm) / (2.0 * eps);
            let ana = dbs[l][b];
            assert!(
                (num - ana).abs() < 2e-2 + 0.05 * num.abs().max(ana.abs()),
                "db[{l}][{b}] numeric {num} vs analytic {ana}"
            );
            checked += 1;
        }
        assert!(checked >= 21);
    }

    #[test]
    fn gater_zeroes_selected_units() {
        struct KillFirst;
        impl ActivationGater for KillFirst {
            fn gate(&self, _layer: usize, input: &Mat) -> Option<Mat> {
                // Zero the first hidden unit of every row. Width of the gated
                // layer differs per layer, so infer from input: we return
                // None for mismatch safety in this test via fixed width.
                let _ = input;
                None
            }
        }
        // Direct mask check through forward: gate layer 0 fully off.
        struct AllOff;
        impl ActivationGater for AllOff {
            fn gate(&self, layer: usize, input: &Mat) -> Option<Mat> {
                if layer == 0 {
                    Some(Mat::zeros(input.rows(), 7))
                } else {
                    None
                }
            }
        }
        let mut rng = Pcg32::seeded(11);
        let net = Mlp::init(&tiny_cfg(), &mut rng);
        let x = Mat::randn(3, 5, 1.0, &mut rng);
        let t = net.forward(&x, &AllOff, None);
        assert!(t.hidden[0].as_slice().iter().all(|&v| v == 0.0));
        // With the first layer dead, logits are input-independent.
        let x2 = Mat::randn(3, 5, 1.0, &mut rng);
        let t2 = net.forward(&x2, &AllOff, None);
        assert!(t.logits.max_abs_diff(&t2.logits) < 1e-6);
        let _ = KillFirst; // silence unused struct warning path
    }

    #[test]
    fn density_reflects_relu_sparsity() {
        let mut rng = Pcg32::seeded(13);
        // Strongly negative biases → all-dead hidden units.
        let cfg = NetConfig { layers: vec![4, 8, 3], weight_sigma: 0.01, bias_init: -5.0 };
        let net = Mlp::init(&cfg, &mut rng);
        let x = Mat::randn(6, 4, 1.0, &mut rng);
        let t = net.forward(&x, &NoGater, None);
        assert_eq!(Mlp::mean_density(&t), 0.0);
    }
}
