//! The reference neural-network trainer — a feature-for-feature rebuild of
//! the substrate the paper used (Palm's Deep Learning Toolbox, §3.5):
//! rectified-linear hidden units, softmax + negative log-likelihood output,
//! dropout (p = 0.5 on hidden layers), ℓ1 activation penalty (Eq. 7),
//! ℓ2 weight penalty, max-norm constraint, and SGD with the paper's
//! learning-rate decay and momentum growth schedules.
//!
//! Conditional computation hooks in through [`ActivationGater`]: the forward
//! pass asks the gater for a 0/1 mask per hidden layer (the paper's `S_l`,
//! Eq. 5) and multiplies it into the post-ReLU activations — "the activation
//! estimator is immediately applied before the next layer activations are
//! used" (§3.5). Training backpropagates through the mask exactly like a
//! ReLU zero: gated units receive no gradient.

pub mod activations;
pub mod mlp;
pub mod optimizer;
pub mod trainer;

pub use mlp::{ActivationGater, ForwardTrace, Mlp, NoGater};
pub use optimizer::SgdMomentum;
pub use trainer::{EpochStats, TrainOptions, Trainer};
