//! SGD with momentum, the paper's schedules (§3.5), ℓ2 weight decay, and the
//! max-norm constraint (Table 1 "Maximum Norm").

use super::mlp::Mlp;
use crate::config::TrainConfig;
use crate::linalg::Mat;

/// Momentum SGD state.
pub struct SgdMomentum {
    vel_w: Vec<Mat>,
    vel_b: Vec<Vec<f32>>,
    /// Current epoch (drives both schedules).
    epoch: usize,
    cfg: TrainConfig,
}

impl SgdMomentum {
    pub fn new(net: &Mlp, cfg: TrainConfig) -> SgdMomentum {
        SgdMomentum {
            vel_w: net.weights.iter().map(|w| Mat::zeros(w.rows(), w.cols())).collect(),
            vel_b: net.biases.iter().map(|b| vec![0.0; b.len()]).collect(),
            epoch: 0,
            cfg,
        }
    }

    /// γₙ = γ₀ · λⁿ (§3.5).
    pub fn learning_rate(&self) -> f32 {
        self.cfg.lr * self.cfg.lr_decay.powi(self.epoch as i32)
    }

    /// νₙ = min(ν_max, ν₀ · βⁿ) (§3.5; the paper's `max(...)` is a typo —
    /// momentum grows toward its ceiling).
    pub fn momentum(&self) -> f32 {
        (self.cfg.momentum * self.cfg.momentum_growth.powi(self.epoch as i32))
            .min(self.cfg.max_momentum)
    }

    /// Advance the schedules at an epoch boundary.
    pub fn next_epoch(&mut self) {
        self.epoch += 1;
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Apply one minibatch update:
    /// `v ← ν·v − γ·(∇W + ℓ2·W)`, `W ← W + v`, then max-norm projection of
    /// each unit's incoming weight column.
    pub fn step(&mut self, net: &mut Mlp, dws: &[Mat], dbs: &[Vec<f32>]) {
        let lr = self.learning_rate();
        let mu = self.momentum();
        let l2 = self.cfg.l2_weight;
        for l in 0..net.depth() {
            {
                let vw = &mut self.vel_w[l];
                let w = &mut net.weights[l];
                let dw = &dws[l];
                debug_assert_eq!(vw.shape(), dw.shape());
                let (vs, ws, ds) =
                    (vw.as_mut_slice(), w.as_mut_slice(), dw.as_slice());
                for i in 0..vs.len() {
                    vs[i] = mu * vs[i] - lr * (ds[i] + l2 * ws[i]);
                    ws[i] += vs[i];
                }
            }
            {
                let vb = &mut self.vel_b[l];
                let b = &mut net.biases[l];
                let db = &dbs[l];
                for i in 0..vb.len() {
                    vb[i] = mu * vb[i] - lr * db[i];
                    b[i] += vb[i];
                }
            }
            if self.cfg.max_norm > 0.0 {
                clamp_column_norms(&mut net.weights[l], self.cfg.max_norm);
            }
        }
    }
}

/// Project each column (a hidden unit's incoming weights) onto the ℓ2 ball of
/// radius `max_norm`.
pub fn clamp_column_norms(w: &mut Mat, max_norm: f32) {
    let (rows, cols) = w.shape();
    for j in 0..cols {
        let mut sq = 0.0f64;
        for i in 0..rows {
            let v = w[(i, j)] as f64;
            sq += v * v;
        }
        let norm = sq.sqrt() as f32;
        if norm > max_norm {
            let scale = max_norm / norm;
            for i in 0..rows {
                w[(i, j)] *= scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentProfile, NetConfig};
    use crate::util::Pcg32;

    fn cfg() -> TrainConfig {
        let mut c = ExperimentProfile::mnist_tiny().train;
        c.lr = 0.1;
        c.lr_decay = 0.9;
        c.momentum = 0.5;
        c.momentum_growth = 1.2;
        c.max_momentum = 0.8;
        c.l2_weight = 0.0;
        c.max_norm = 0.0;
        c
    }

    fn tiny_net(rng: &mut Pcg32) -> Mlp {
        Mlp::init(&NetConfig { layers: vec![3, 4, 2], weight_sigma: 0.3, bias_init: 0.0 }, rng)
    }

    #[test]
    fn schedules_follow_paper() {
        let mut rng = Pcg32::seeded(1);
        let net = tiny_net(&mut rng);
        let mut opt = SgdMomentum::new(&net, cfg());
        assert!((opt.learning_rate() - 0.1).abs() < 1e-7);
        assert!((opt.momentum() - 0.5).abs() < 1e-7);
        opt.next_epoch();
        assert!((opt.learning_rate() - 0.09).abs() < 1e-7);
        assert!((opt.momentum() - 0.6).abs() < 1e-7);
        for _ in 0..10 {
            opt.next_epoch();
        }
        assert!((opt.momentum() - 0.8).abs() < 1e-7, "momentum capped at max");
    }

    #[test]
    fn step_descends_simple_quadratic() {
        // Minimize ||W||² via grads dW = 2W: weights must shrink.
        let mut rng = Pcg32::seeded(2);
        let mut net = tiny_net(&mut rng);
        let mut opt = SgdMomentum::new(&net, cfg());
        let norm0: f32 = net.weights.iter().map(|w| w.fro_norm()).sum();
        for _ in 0..50 {
            let dws: Vec<Mat> = net.weights.iter().map(|w| w.map(|x| 2.0 * x)).collect();
            let dbs: Vec<Vec<f32>> =
                net.biases.iter().map(|b| b.iter().map(|&x| 2.0 * x).collect()).collect();
            opt.step(&mut net, &dws, &dbs);
        }
        let norm1: f32 = net.weights.iter().map(|w| w.fro_norm()).sum();
        assert!(norm1 < norm0 * 0.2, "weights should shrink: {norm0} -> {norm1}");
    }

    #[test]
    fn l2_decay_shrinks_weights_with_zero_grads() {
        let mut rng = Pcg32::seeded(3);
        let mut net = tiny_net(&mut rng);
        let mut c = cfg();
        c.l2_weight = 0.5;
        let mut opt = SgdMomentum::new(&net, c);
        let w0 = net.weights[0].fro_norm();
        let dws: Vec<Mat> = net.weights.iter().map(|w| Mat::zeros(w.rows(), w.cols())).collect();
        let dbs: Vec<Vec<f32>> = net.biases.iter().map(|b| vec![0.0; b.len()]).collect();
        for _ in 0..10 {
            opt.step(&mut net, &dws, &dbs);
        }
        assert!(net.weights[0].fro_norm() < w0);
    }

    #[test]
    fn max_norm_clamps_columns() {
        let mut w = Mat::from_vec(2, 2, vec![3.0, 0.1, 4.0, 0.1]);
        clamp_column_norms(&mut w, 1.0);
        // Column 0 had norm 5 → scaled to 1; column 1 untouched.
        let n0 = (w[(0, 0)] * w[(0, 0)] + w[(1, 0)] * w[(1, 0)]).sqrt();
        assert!((n0 - 1.0).abs() < 1e-5);
        assert!((w[(0, 1)] - 0.1).abs() < 1e-7);
    }

    #[test]
    fn momentum_accelerates_along_constant_gradient() {
        let mut rng = Pcg32::seeded(5);
        let mut net = tiny_net(&mut rng);
        net.weights[0].as_mut_slice().fill(0.0);
        let mut opt = SgdMomentum::new(&net, cfg());
        let ones: Vec<Mat> =
            net.weights.iter().map(|w| Mat::full(w.rows(), w.cols(), 1.0)).collect();
        let dbs: Vec<Vec<f32>> = net.biases.iter().map(|b| vec![0.0; b.len()]).collect();
        opt.step(&mut net, &ones, &dbs);
        let after1 = -net.weights[0][(0, 0)];
        opt.step(&mut net, &ones, &dbs);
        let after2 = -net.weights[0][(0, 0)] - after1;
        assert!(after2 > after1, "second step larger under momentum: {after1} vs {after2}");
    }
}
