//! Span tracing + the batch flight recorder: the serving observability
//! plane's std-only core.
//!
//! Three pieces:
//!
//! - a process-wide **enable flag** ([`enabled`] / [`set_enabled`], env
//!   `CONDCOMP_TRACE=1`, config `server.trace` / CLI `--trace`). Every
//!   instrumentation site guards on it with one relaxed atomic load, so a
//!   tracing-off server pays a branch per span site and nothing else;
//! - **span records** ([`Span`]): a static name (`recv`, `route`, `queue`,
//!   `lease`, `estimator`, `kernel`, `reply`, `autotune_measure`, …), an
//!   optional static detail (the [`crate::condcomp::KernelId`] for kernel
//!   spans), and a measured duration. Spans are created through
//!   [`crate::exec::MetricsScope::span`], which both feeds the per-series
//!   latency histograms (`span_<label>` in the `stats` snapshot) and, on
//!   the shard executors, collects into a per-batch [`SpanCollector`];
//! - the **flight recorder** ([`FlightRecorder`]): a fixed-size ring of the
//!   last N drained-batch records — shard, rows, kernels chosen, queue
//!   depth at drain, per-span timings — dumpable over the wire via the
//!   `trace` protocol op / `condcomp trace` subcommand, and auto-dumped to
//!   stderr when a shard executor panics.
//!
//! The invariant carried over from the rest of the stack: tracing changes
//! observability only, never results — span guards are inert when the flag
//! is off, and the recorder is written only on traced batches.

use crate::io::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;

/// Tri-state enable flag: lazily initialized from the environment on first
/// query, overridable any time via [`set_enabled`].
static STATE: AtomicU8 = AtomicU8::new(UNINIT);
const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

/// Is span tracing on? One relaxed atomic load — the whole cost of a span
/// site on the tracing-off hot path.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    let on = std::env::var("CONDCOMP_TRACE")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    // Racing an explicit set_enabled: the explicit call wins.
    let _ = STATE.compare_exchange(
        UNINIT,
        if on { ON } else { OFF },
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    STATE.load(Ordering::Relaxed) == ON
}

/// Turn tracing on or off process-wide (config/CLI knob; the bench harness
/// toggles it to measure the tracing-on overhead column).
pub fn set_enabled(on: bool) {
    STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
}

/// Serializes tests (and test-driven bench runs) that flip the
/// process-wide flag — unit tests in one binary run concurrently.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// One timed span. `name` and `detail` are static so recording a span
/// allocates nothing; the rendered label is `name` or `name_detail`
/// (`kernel` + `masked_simd` → `kernel_masked_simd`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    pub name: &'static str,
    pub detail: Option<&'static str>,
    pub micros: f64,
}

impl Span {
    pub fn label(&self) -> String {
        match self.detail {
            Some(d) => format!("{}_{d}", self.name),
            None => self.name.to_string(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.label())),
            ("us", Json::Num(self.micros)),
        ])
    }
}

/// Per-executor span sink: the shard executor's [`crate::exec::MetricsScope`]
/// carries one, span guards push into it, and the executor drains it into a
/// [`FlightRecord`] after each batch. The mutex is effectively uncontended —
/// only the owning executor thread writes during a batch.
#[derive(Default)]
pub struct SpanCollector {
    spans: Mutex<Vec<Span>>,
}

impl SpanCollector {
    pub fn push(&self, span: Span) {
        self.spans.lock().unwrap().push(span);
    }

    /// Take everything collected since the last drain.
    pub fn drain(&self) -> Vec<Span> {
        std::mem::take(&mut *self.spans.lock().unwrap())
    }
}

/// One drained batch, as the flight recorder remembers it.
#[derive(Clone, Debug)]
pub struct FlightRecord {
    /// Monotonic record number (global across shards), so a dump shows
    /// interleaving order even though the ring is per-server.
    pub seq: u64,
    pub shard: usize,
    /// Total rows executed in the batch.
    pub rows: usize,
    /// Requests coalesced into the batch.
    pub items: usize,
    /// Protocol mode label (`ae` / `control`).
    pub mode: &'static str,
    /// Kernels the cost router picked, one per conditional layer (derived
    /// from the batch's `kernel` spans; empty for dense-mode batches).
    pub kernels: Vec<String>,
    /// Shard queue depth right after this batch was drained.
    pub queue_depth: usize,
    /// Shard queue pressure (depth / max_queue_depth, 0 when unbounded) at
    /// drain time — the signal the quality-elastic dispatch keys off.
    pub pressure: f64,
    /// Oldest item's queue wait (enqueue → drain), µs.
    pub queue_wait_us: f64,
    /// Drain → replies-sent wall clock, µs. The per-span timings partition
    /// this (minus inter-span bookkeeping).
    pub total_us: f64,
    pub spans: Vec<Span>,
}

impl FlightRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq", Json::Num(self.seq as f64)),
            ("shard", Json::Num(self.shard as f64)),
            ("rows", Json::Num(self.rows as f64)),
            ("items", Json::Num(self.items as f64)),
            ("mode", Json::Str(self.mode.to_string())),
            (
                "kernels",
                Json::Arr(self.kernels.iter().map(|k| Json::Str(k.clone())).collect()),
            ),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("pressure", Json::Num(self.pressure)),
            ("queue_wait_us", Json::Num(self.queue_wait_us)),
            ("total_us", Json::Num(self.total_us)),
            (
                "spans",
                Json::Arr(self.spans.iter().map(Span::to_json).collect()),
            ),
        ])
    }
}

/// Fixed-size ring of the last N [`FlightRecord`]s (`server.trace_ring` /
/// `--trace-ring`). Writers push post-batch (one short lock per traced
/// batch); readers dump the whole ring as JSON.
pub struct FlightRecorder {
    capacity: usize,
    seq: AtomicU64,
    ring: Mutex<VecDeque<FlightRecord>>,
}

impl FlightRecorder {
    pub fn new(capacity: usize) -> FlightRecorder {
        let capacity = capacity.max(1);
        FlightRecorder {
            capacity,
            seq: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(capacity)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Claim the next record number (cheap, lock-free).
    pub fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    pub fn record(&self, rec: FlightRecord) {
        let mut ring = self.ring.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(rec);
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the ring (oldest first) — tests and the panic dump path.
    pub fn records(&self) -> Vec<FlightRecord> {
        self.ring.lock().unwrap().iter().cloned().collect()
    }

    /// The wire dump: `{"ring_capacity": N, "recorded": M, "records": [...]}`
    /// where `recorded` counts every batch ever traced (the ring keeps the
    /// last `ring_capacity` of them).
    pub fn dump(&self) -> Json {
        let ring = self.ring.lock().unwrap();
        Json::obj(vec![
            ("ring_capacity", Json::Num(self.capacity as f64)),
            ("recorded", Json::Num(self.seq.load(Ordering::Relaxed) as f64)),
            (
                "records",
                Json::Arr(ring.iter().map(FlightRecord::to_json).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, shard: usize) -> FlightRecord {
        FlightRecord {
            seq,
            shard,
            rows: 2,
            items: 2,
            mode: "ae",
            kernels: vec!["masked".into()],
            queue_depth: 1,
            pressure: 0.25,
            queue_wait_us: 10.0,
            total_us: 120.0,
            spans: vec![
                Span { name: "prep", detail: None, micros: 5.0 },
                Span { name: "kernel", detail: Some("masked"), micros: 100.0 },
            ],
        }
    }

    #[test]
    fn enable_flag_toggles() {
        let _serial = test_lock();
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn span_labels_compose_name_and_detail() {
        let s = Span { name: "kernel", detail: Some("dense_simd"), micros: 1.0 };
        assert_eq!(s.label(), "kernel_dense_simd");
        let s = Span { name: "estimator", detail: None, micros: 1.0 };
        assert_eq!(s.label(), "estimator");
    }

    #[test]
    fn collector_drains_to_empty() {
        let c = SpanCollector::default();
        c.push(Span { name: "a", detail: None, micros: 1.0 });
        c.push(Span { name: "b", detail: None, micros: 2.0 });
        let spans = c.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "a");
        assert!(c.drain().is_empty(), "drain takes ownership");
    }

    #[test]
    fn ring_keeps_last_n_records() {
        let fr = FlightRecorder::new(3);
        assert_eq!(fr.capacity(), 3);
        assert!(fr.is_empty());
        for shard in 0..5 {
            let seq = fr.next_seq();
            fr.record(rec(seq, shard));
        }
        assert_eq!(fr.len(), 3);
        let records = fr.records();
        assert_eq!(
            records.iter().map(|r| r.seq).collect::<Vec<_>>(),
            vec![2, 3, 4],
            "oldest records evicted first"
        );
        // Zero capacity is clamped, not a panic.
        assert_eq!(FlightRecorder::new(0).capacity(), 1);
    }

    #[test]
    fn dump_is_valid_json_with_schema() {
        let fr = FlightRecorder::new(8);
        let seq = fr.next_seq();
        fr.record(rec(seq, 1));
        let dump = fr.dump().to_string();
        let parsed = Json::parse(&dump).unwrap();
        assert_eq!(parsed.get("ring_capacity").unwrap().as_f64(), Some(8.0));
        assert_eq!(parsed.get("recorded").unwrap().as_f64(), Some(1.0));
        let records = parsed.get("records").unwrap().as_arr().unwrap();
        assert_eq!(records.len(), 1);
        let r = &records[0];
        for key in [
            "seq", "shard", "rows", "items", "mode", "kernels", "queue_depth",
            "pressure", "queue_wait_us", "total_us", "spans",
        ] {
            assert!(r.get(key).is_some(), "record missing {key}: {dump}");
        }
        let spans = r.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans[1].get("name").unwrap().as_str(), Some("kernel_masked"));
        assert_eq!(spans[1].get("us").unwrap().as_f64(), Some(100.0));
    }
}
