//! A criterion-lite measurement harness (the real criterion is unavailable
//! offline): warmup, adaptive iteration count targeting a fixed measurement
//! budget, robust summary statistics, and throughput reporting.
//!
//! Used by every target in `benches/` (registered with `harness = false`).
//! The [`sweep`] submodule packages the dense-vs-masked-vs-parallel sweep
//! shared by `benches/bench_gemm.rs` and the `condcomp bench` subcommand
//! (which writes it as `BENCH_parallel.json`).

pub mod sweep;

use crate::util::stats::Summary;
use crate::util::Timer;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary (seconds).
    pub time: Summary,
    /// Optional work units per iteration (FLOPs, rows, requests).
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// Units per second, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.time.median)
    }

    /// Render one human-readable line.
    pub fn line(&self) -> String {
        let med = self.time.median;
        let t = if med >= 1.0 {
            format!("{med:.3} s")
        } else if med >= 1e-3 {
            format!("{:.3} ms", med * 1e3)
        } else {
            format!("{:.1} us", med * 1e6)
        };
        let spread = format!("±{:.1}%", 100.0 * self.time.rel_std());
        match self.throughput() {
            Some(tp) if tp >= 1e9 => format!("{:<44} {t:>12} {spread:>8}  {:.2} G/s", self.name, tp / 1e9),
            Some(tp) if tp >= 1e6 => format!("{:<44} {t:>12} {spread:>8}  {:.2} M/s", self.name, tp / 1e6),
            Some(tp) => format!("{:<44} {t:>12} {spread:>8}  {tp:.0} /s", self.name),
            None => format!("{:<44} {t:>12} {spread:>8}", self.name),
        }
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup budget in seconds.
    pub warmup_s: f64,
    /// Measurement budget in seconds.
    pub measure_s: f64,
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations (keeps tiny benches bounded).
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_s: 0.2, measure_s: 1.0, min_iters: 5, max_iters: 1000 }
    }
}

/// Quick config for benches embedded in CI-ish runs.
pub fn quick() -> BenchConfig {
    BenchConfig { warmup_s: 0.05, measure_s: 0.25, min_iters: 3, max_iters: 200 }
}

/// Measure a closure. The closure's return value is black-boxed so the work
/// is not optimized away.
pub fn bench<T>(name: &str, cfg: &BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration.
    let mut calib_iters = 0usize;
    let warm = Timer::start();
    while warm.elapsed_s() < cfg.warmup_s || calib_iters == 0 {
        std::hint::black_box(f());
        calib_iters += 1;
        if calib_iters > 10_000 {
            break;
        }
    }
    let per_iter = (warm.elapsed_s() / calib_iters as f64).max(1e-9);
    let iters = ((cfg.measure_s / per_iter) as usize).clamp(cfg.min_iters, cfg.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), time: Summary::of(&samples), units_per_iter: None }
}

/// Measure with a throughput denominator (units of work per iteration).
pub fn bench_with_units<T>(
    name: &str,
    cfg: &BenchConfig,
    units_per_iter: f64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.units_per_iter = Some(units_per_iter);
    r
}

/// Print a standard bench header.
pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>12} {:>8}", "benchmark", "median", "spread");
    println!("{}", "-".repeat(78));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let cfg = BenchConfig { warmup_s: 0.01, measure_s: 0.02, min_iters: 3, max_iters: 50 };
        let r = bench("spin", &cfg, || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.time.median > 0.0);
        assert!(r.time.n >= 3);
        assert!(!r.line().is_empty());
    }

    #[test]
    fn throughput_computed() {
        let cfg = BenchConfig { warmup_s: 0.01, measure_s: 0.02, min_iters: 3, max_iters: 50 };
        let r = bench_with_units("units", &cfg, 1000.0, || std::hint::black_box(42));
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.line().contains("/s"));
    }

    #[test]
    fn respects_iter_bounds() {
        let cfg = BenchConfig { warmup_s: 0.005, measure_s: 0.01, min_iters: 4, max_iters: 6 };
        let r = bench("bounded", &cfg, || std::thread::sleep(std::time::Duration::from_micros(10)));
        assert!(r.time.n >= 4 && r.time.n <= 6);
    }
}
