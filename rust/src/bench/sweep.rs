//! The dense-vs-masked-vs-parallel wall-clock sweep behind `condcomp bench`
//! and `benches/bench_gemm.rs`.
//!
//! Measures, on this machine:
//!
//! - the dense square GEMM (`dim × dim × dim`) serial vs pool-parallel —
//!   the acceptance target is ≥ 2× at `dim = 512` on a multi-core box;
//! - the masked layer at α ∈ {0.05, 0.25, 0.5, 1.0} × threads ∈ {1, N};
//! - the resulting masked-vs-dense per-FLOP cost ratio and the α threshold
//!   where [`crate::condcomp::DispatchPolicy`] flips from masked to dense.
//!
//! [`ParallelSweep::to_json`] renders everything machine-readable
//! (`BENCH_parallel.json`); ROADMAP.md records the last measured threshold.

use super::{bench_with_units, BenchConfig, BenchResult};
use crate::autotune::{Autotuner, LayerThreshold};
use crate::condcomp::registry::LayerOperands;
use crate::condcomp::{DispatchPolicy, KernelId, KernelRegistry, MaskedLayer, QUANT_SIGN_BAND_REL};
use crate::config::{EstimatorConfig, NetConfig};
use crate::exec::ExecCtx;
use crate::coordinator::protocol::{Mode, Request, Response};
use crate::coordinator::server::Client;
use crate::coordinator::{NativeBackend, PoolMode, Server, ServerConfig};
use crate::estimator::{SignEstimator, SignEstimatorSet};
use crate::io::json::Json;
use crate::linalg::{matmul_into, matmul_into_par, Mat, QuantizedLayer};
use crate::nn::Mlp;
use crate::parallel::ThreadPool;
use crate::util::ulp::ulp_diff;
use crate::util::Pcg32;
use std::sync::Arc;

/// One measured configuration.
#[derive(Clone, Debug)]
pub struct SweepRow {
    /// Kernel label: "dense_gemm", "masked_forward", "dense_forward".
    pub kernel: String,
    pub threads: usize,
    /// Mask density for masked rows; `None` for dense kernels.
    pub alpha: Option<f64>,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Work per iteration (FLOPs), for throughput.
    pub flops: f64,
}

impl SweepRow {
    fn from_result(kernel: &str, threads: usize, alpha: Option<f64>, r: &BenchResult) -> SweepRow {
        SweepRow {
            kernel: kernel.to_string(),
            threads,
            alpha,
            median_s: r.time.median,
            flops: r.units_per_iter.unwrap_or(0.0),
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kernel", Json::Str(self.kernel.clone())),
            ("threads", Json::Num(self.threads as f64)),
            ("median_s", Json::Num(self.median_s)),
            ("flops", Json::Num(self.flops)),
            ("gflops_per_s", Json::Num(self.flops / self.median_s.max(1e-12) / 1e9)),
        ];
        if let Some(a) = self.alpha {
            pairs.push(("alpha", Json::Num(a)));
        }
        Json::obj(pairs)
    }
}

/// One registry-kernel measurement at a fixed mask density: the
/// `kernel_sweep` column — dense vs dense_packed vs masked throughput at
/// each α, all through the same registry entry points dispatch routes to.
#[derive(Clone, Debug)]
pub struct KernelSweepRow {
    /// Registry kernel id (`dense`, `dense_packed`, `masked`, …).
    pub kernel: String,
    /// Mask density the kernel ran at.
    pub alpha: f64,
    /// Median seconds per forward.
    pub median_s: f64,
    /// §3.4 FLOPs the kernel executes per forward at this α (dense-work
    /// kernels compute every cell regardless of α).
    pub flops: f64,
}

impl KernelSweepRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", Json::Str(self.kernel.clone())),
            ("alpha", Json::Num(self.alpha)),
            ("median_s", Json::Num(self.median_s)),
            ("flops", Json::Num(self.flops)),
            ("gflops_per_s", Json::Num(self.flops / self.median_s.max(1e-12) / 1e9)),
        ])
    }
}

/// One accuracy-vs-throughput frontier measurement: the `quant_sweep`
/// column — dense/masked raced against their int8 counterparts at a grid
/// density, annotated with what the int8 speed costs (estimator mask
/// agreement and worst-case logit ULP drift vs the same-work float kernel)
/// and with the cell's measured-cost argmin winner.
#[derive(Clone, Debug)]
pub struct QuantSweepRow {
    /// Registry kernel id (`dense`, `dense_i8`, `masked`, `masked_i8`).
    pub kernel: String,
    /// Mask density the kernel ran at.
    pub alpha: f64,
    /// Median seconds per forward.
    pub median_s: f64,
    /// §3.4 op count per forward at this α (the int8 kernels execute the
    /// same counts in ~4× narrower arithmetic).
    pub flops: f64,
    /// Fraction of mask entries on which the full-rank quantized estimator
    /// agrees with the float estimator (1.0 by definition for float rows).
    pub mask_agreement: f64,
    /// Worst-case logit ULP distance vs the same-work-model float kernel,
    /// outside the sign-agreement near-zero band (0 for the float rows —
    /// they *are* their own reference).
    pub ulp_drift: f64,
    /// This kernel wins the measured-cost argmin among the four at this α.
    pub argmin_winner: bool,
}

impl QuantSweepRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", Json::Str(self.kernel.clone())),
            ("alpha", Json::Num(self.alpha)),
            ("median_s", Json::Num(self.median_s)),
            ("flops", Json::Num(self.flops)),
            ("gflops_per_s", Json::Num(self.flops / self.median_s.max(1e-12) / 1e9)),
            ("mask_agreement", Json::Num(self.mask_agreement)),
            ("ulp_drift", Json::Num(self.ulp_drift)),
            ("argmin_winner", Json::Bool(self.argmin_winner)),
        ])
    }
}

/// Worst-case ULP distance between `got` and its float reference, excluding
/// cells where the reference sits inside the sign-agreement near-zero band
/// (ULP distance diverges toward 0.0 while the absolute quantization error
/// stays tiny — the same band [`crate::condcomp::EquivalenceTier::SignAgree`]
/// excludes).
fn drift_ulps_outside_band(got: &Mat, want: &Mat) -> f64 {
    let band = want.as_slice().iter().fold(0.0f32, |m, v| m.max(v.abs())) * QUANT_SIGN_BAND_REL;
    got.as_slice()
        .iter()
        .zip(want.as_slice())
        .filter(|(_, w)| w.abs() > band)
        .map(|(g, w)| ulp_diff(*g, *w))
        .max()
        .unwrap_or(0) as f64
}

/// One serving-throughput measurement at a fixed batcher shard count: the
/// loopback arm of the sweep (real `Server` + TCP `Client`s), so
/// `BENCH_parallel.json` records how throughput scales with `--shards`.
#[derive(Clone, Debug)]
pub struct ShardRow {
    /// Batcher shards the server ran with.
    pub shards: usize,
    /// Concurrent loopback clients.
    pub clients: usize,
    /// Total predict requests completed (all clients).
    pub requests: usize,
    /// Wall-clock for the whole run.
    pub elapsed_s: f64,
    /// Requests per second.
    pub rps: f64,
}

impl ShardRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("rps", Json::Num(self.rps)),
        ])
    }
}

/// One serving-throughput measurement at a fixed worker-replica count: a
/// coordinator (`RemoteBackend`) fronting N in-process worker servers over
/// loopback TCP, so `BENCH_parallel.json` records what multi-process
/// serving costs/buys against the same model.
#[derive(Clone, Debug)]
pub struct ReplicaRow {
    /// Worker replicas behind the coordinator.
    pub workers: usize,
    /// Concurrent loopback clients.
    pub clients: usize,
    /// Total predict requests completed (all clients).
    pub requests: usize,
    /// Wall-clock for the whole run.
    pub elapsed_s: f64,
    /// Requests per second.
    pub rps: f64,
}

impl ReplicaRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("elapsed_s", Json::Num(self.elapsed_s)),
            ("rps", Json::Num(self.rps)),
        ])
    }
}

/// Leased executors vs the PR-3 private-pool baseline at one shard count:
/// the column that shows pool slicing costs no throughput while halving
/// the spawned thread count.
#[derive(Clone, Debug)]
pub struct LeaseVsPrivateRow {
    pub shards: usize,
    pub clients: usize,
    /// Requests/s with shard executors leasing slices of the shared pool.
    pub rps_lease: f64,
    /// Requests/s with a private `ThreadPool` per shard (baseline).
    pub rps_private: f64,
}

impl LeaseVsPrivateRow {
    /// Throughput ratio leased / private (1.0 = parity, > 1 = lease wins).
    pub fn lease_over_private(&self) -> f64 {
        self.rps_lease / self.rps_private.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("rps_lease", Json::Num(self.rps_lease)),
            ("rps_private", Json::Num(self.rps_private)),
            ("lease_over_private", Json::Num(self.lease_over_private())),
        ])
    }
}

/// Serve throughput with span tracing off vs on (same server shape, same
/// request mix): the `trace_overhead` column. The acceptance bar is that
/// the *off* arm stays within noise of an untraced build — tracing is a
/// relaxed atomic load per span site when disabled — and the column also
/// documents what turning tracing on actually costs.
#[derive(Clone, Debug)]
pub struct TraceOverheadRow {
    pub shards: usize,
    pub clients: usize,
    /// Requests/s with tracing disabled (the production default).
    pub rps_off: f64,
    /// Requests/s with tracing enabled (spans + flight recorder active).
    pub rps_on: f64,
}

impl TraceOverheadRow {
    /// Throughput ratio traced / untraced (1.0 = tracing is free).
    pub fn on_over_off(&self) -> f64 {
        self.rps_on / self.rps_off.max(1e-9)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::Num(self.shards as f64)),
            ("clients", Json::Num(self.clients as f64)),
            ("rps_off", Json::Num(self.rps_off)),
            ("rps_on", Json::Num(self.rps_on)),
            ("on_over_off", Json::Num(self.on_over_off())),
        ])
    }
}

/// One overload arm: a bounded-admission server driven at a fixed multiple
/// of its measured saturation throughput by pipelining loopback clients.
/// The `overload_sweep` column records how admission control degrades —
/// accepted throughput should hold near saturation while the shed rate
/// absorbs the excess, with and without quality-elastic dispatch.
#[derive(Clone, Debug)]
pub struct OverloadRow {
    /// Offered load as a multiple of the measured saturation rps.
    pub offered_x: f64,
    /// Quality-elastic dispatch on for this arm.
    pub elastic: bool,
    /// Requests offered per second (sends actually realized).
    pub offered_rps: f64,
    /// Requests answered with logits per second.
    pub accepted_rps: f64,
    /// Fraction of offered requests shed with an overloaded reply.
    pub shed_rate: f64,
    /// p99 server-side latency of *accepted* requests, milliseconds.
    pub p99_ms: f64,
}

impl OverloadRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_x", Json::Num(self.offered_x)),
            ("elastic", Json::Bool(self.elastic)),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("accepted_rps", Json::Num(self.accepted_rps)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("p99_ms", Json::Num(self.p99_ms)),
        ])
    }
}

/// The complete sweep result.
#[derive(Clone, Debug)]
pub struct ParallelSweep {
    pub dim: usize,
    pub batch: usize,
    pub threads_max: usize,
    pub rows: Vec<SweepRow>,
    /// Parallel dense GEMM speedup over serial at `dim³`.
    pub dense_parallel_speedup: f64,
    /// Measured masked-vs-dense per-FLOP cost ratio (threads = N arm).
    pub measured_cost_ratio: f64,
    /// α where the dispatch policy flips from masked to dense
    /// (`1 / measured_cost_ratio`).
    pub density_threshold: f64,
    /// Per-layer fitted thresholds for the requested model's hidden-layer
    /// shapes (the autotune harness's quick fit — `condcomp calibrate`
    /// runs the same fit under a configurable budget and persists it).
    pub per_layer: Vec<LayerThreshold>,
    /// Registry-kernel throughput at each grid density (dense vs
    /// dense_packed vs masked through the registry entry points).
    pub kernel_sweep: Vec<KernelSweepRow>,
    /// Scalar-vs-SIMD head-to-head at each grid density: the fixed five-way
    /// dense / dense_packed / dense_simd / masked / masked_simd race,
    /// always over the full builtin registry (a `--kernels` restriction
    /// narrows routing, not this comparison column).
    pub simd_sweep: Vec<KernelSweepRow>,
    /// The accuracy-vs-throughput frontier: float vs int8 kernels at each
    /// grid density, with mask agreement, logit ULP drift, and the
    /// measured-cost argmin winner per cell. Like `simd_sweep`, always the
    /// fixed four-way race over the full builtin registry.
    pub quant_sweep: Vec<QuantSweepRow>,
    /// Serving throughput at each measured batcher shard count (leased
    /// executors — the production configuration).
    pub shard_sweep: Vec<ShardRow>,
    /// Serving throughput with a coordinator over {1, 2} worker replicas
    /// (in-process workers, loopback TCP between coordinator and workers).
    pub replica_sweep: Vec<ReplicaRow>,
    /// Leased vs private-pool executor throughput at each shard count.
    pub lease_vs_private: Vec<LeaseVsPrivateRow>,
    /// Serve throughput with span tracing off vs on.
    pub trace_overhead: TraceOverheadRow,
    /// Bounded-admission behavior at offered loads of {0.5, 1, 2, 4}× the
    /// measured saturation throughput, with elastic dispatch off and on.
    pub overload_sweep: Vec<OverloadRow>,
}

/// Densities the sweep measures (the issue's α grid).
pub const ALPHA_GRID: [f64; 4] = [0.05, 0.25, 0.5, 1.0];

/// Offered-load multiples of measured saturation for the overload column.
pub const OVERLOAD_GRID: [f64; 4] = [0.5, 1.0, 2.0, 4.0];

/// Run the full sweep. `dim` is the square GEMM dimension (512 for the
/// acceptance target), `batch` the masked layer's batch rows, `threads_max`
/// the parallel arm's pool size, `layer_sizes` the model layer widths whose
/// hidden shapes get individually fitted thresholds, `kernels` an optional
/// registry allow-list (`--kernels`) restricting the kernel sweep and the
/// per-layer fit.
pub fn run_parallel_sweep(
    cfg: &BenchConfig,
    dim: usize,
    batch: usize,
    threads_max: usize,
    layer_sizes: &[usize],
    kernels: Option<&[KernelId]>,
) -> ParallelSweep {
    let registry = match kernels {
        Some(allow) => KernelRegistry::builtin()
            .restricted(allow)
            .expect("validated allow-list"),
        None => KernelRegistry::builtin(),
    };
    let threads_max = threads_max.max(1);
    let mut rng = Pcg32::seeded(0xBE9C);
    let mut rows = Vec::new();

    // --- dense square GEMM, serial vs parallel -------------------------
    let a = Mat::randn(dim, dim, 1.0, &mut rng);
    let b = Mat::randn(dim, dim, 0.05, &mut rng);
    let mut c = Mat::zeros(dim, dim);
    let gemm_flops = 2.0 * (dim as f64).powi(3);
    let mut dense_times = [0.0f64; 2];
    for (slot, &threads) in [1usize, threads_max].iter().enumerate() {
        let pool = ThreadPool::new(threads);
        let r = bench_with_units(
            &format!("dense_gemm {dim}x{dim}x{dim} threads={threads}"),
            cfg,
            gemm_flops,
            || {
                if threads == 1 {
                    matmul_into(&a, &b, &mut c);
                } else {
                    matmul_into_par(&a, &b, &mut c, &pool);
                }
            },
        );
        dense_times[slot] = r.time.median;
        rows.push(SweepRow::from_result("dense_gemm", threads, None, &r));
    }
    let dense_parallel_speedup = dense_times[0] / dense_times[1].max(1e-12);

    // --- masked layer across the α grid × {1, N} threads ---------------
    let x = Mat::randn(batch, dim, 0.5, &mut rng);
    let bias = vec![0.0f32; dim];
    let layer = MaskedLayer::new(&b, &bias);
    let layer_flops = 2.0 * (batch * dim * dim) as f64;
    let mut out = Mat::zeros(batch, dim);
    // One mask per α, drawn up front so every thread arm benches the exact
    // same work (otherwise mask-sampling variance pollutes the 1-vs-N rows).
    let masks: Vec<(f64, Mat)> = ALPHA_GRID
        .iter()
        .map(|&alpha| {
            let mask = Mat::from_fn(batch, dim, |_, _| {
                if rng.bernoulli(alpha as f32) { 1.0 } else { 0.0 }
            });
            (alpha, mask)
        })
        .collect();
    let mut masked_full_par = 0.0f64;
    let mut dense_gemm_batch_par = 0.0f64;
    for &threads in &[1usize, threads_max] {
        let pool = ThreadPool::new(threads);
        // The dense GEMM at the *layer's* shape (batch × dim × dim) — this
        // is the kernel the backend's dense dispatch arm actually runs, so
        // the threshold must come from it, not from scaling the dim³ time.
        let r = bench_with_units(
            &format!("dense_gemm_batch {batch}x{dim}x{dim} threads={threads}"),
            cfg,
            layer_flops,
            || matmul_into_par(&x, &b, &mut out, &pool),
        );
        if threads == threads_max {
            dense_gemm_batch_par = r.time.median;
        }
        rows.push(SweepRow::from_result("dense_gemm_batch", threads, None, &r));
        let r = bench_with_units(
            &format!("dense_forward batch={batch} threads={threads}"),
            cfg,
            layer_flops,
            || layer.forward_dense_par(&x, &mut out, &pool),
        );
        rows.push(SweepRow::from_result("dense_forward", threads, None, &r));
        for &(alpha, ref mask) in &masks {
            let r = bench_with_units(
                &format!("masked_forward α={alpha} threads={threads}"),
                cfg,
                layer_flops * alpha,
                || layer.forward_masked_par(&x, mask, &mut out, &pool),
            );
            if threads == threads_max && alpha == 1.0 {
                masked_full_par = r.time.median;
            }
            rows.push(SweepRow::from_result("masked_forward", threads, Some(alpha), &r));
        }
    }

    // The dispatch threshold, measured: masked time scales ~linearly in α,
    // so the flip point is t_dense / t_masked(α=1). t_dense is the parallel
    // axpy GEMM at the layer's own shape — exactly the kernel the backend's
    // DenseParallel arm runs (forward_dense_par is measured for the report
    // but deliberately excluded from the threshold).
    let dense_ref = dense_gemm_batch_par;
    let measured_cost_ratio = (masked_full_par / dense_ref.max(1e-12)).max(1e-6);
    let policy = DispatchPolicy::with_cost_ratio(measured_cost_ratio);

    // --- registry kernels head-to-head across the α grid ----------------
    // The kernel_sweep column: every registered (and allowed) kernel at the
    // layer shape, through the exact registry entry points the cost router
    // dispatches to — dense vs dense_packed race bitwise-identical outputs,
    // masked races its α-proportional work against them.
    let mut kernel_sweep = Vec::new();
    {
        let pool = ThreadPool::new(threads_max);
        let mut ctx = ExecCtx::full(&pool);
        let layer = MaskedLayer::new(&b, &bias);
        let quant = QuantizedLayer::new(&layer.wt, &layer.bias);
        let ops = LayerOperands::new(&b, &layer).with_quant(&quant);
        for &(alpha, ref mask) in &masks {
            for kernel in registry.iter() {
                let work = if kernel.id().work().scales_with_alpha() {
                    layer_flops * alpha
                } else {
                    layer_flops
                };
                let r = bench_with_units(
                    &format!("kernel_{} α={alpha} threads={threads_max}", kernel.id()),
                    cfg,
                    work,
                    || {
                        let _ = kernel.run(&ops, &x, mask, &mut ctx, &mut out);
                    },
                );
                kernel_sweep.push(KernelSweepRow {
                    kernel: kernel.id().as_str().to_string(),
                    alpha,
                    median_s: r.time.median,
                    flops: work,
                });
            }
        }
    }

    // --- scalar vs SIMD kernels across the α grid ------------------------
    // The simd_sweep column: the five in-tree kernels raced at the layer
    // shape regardless of any `--kernels` restriction, so the JSON always
    // answers "does dense_simd beat dense on this machine?" (the perf
    // acceptance criterion) even for a restricted bench run.
    let mut simd_sweep = Vec::new();
    {
        let builtin = KernelRegistry::builtin();
        let pool = ThreadPool::new(threads_max);
        let mut ctx = ExecCtx::full(&pool);
        let layer = MaskedLayer::new(&b, &bias);
        let ops = LayerOperands::new(&b, &layer);
        for &(alpha, ref mask) in &masks {
            for id in [
                KernelId::DENSE,
                KernelId::DENSE_PACKED,
                KernelId::DENSE_SIMD,
                KernelId::MASKED,
                KernelId::MASKED_SIMD,
            ] {
                let kernel = builtin.get(id).expect("builtin kernel");
                let work = if id.work().scales_with_alpha() {
                    layer_flops * alpha
                } else {
                    layer_flops
                };
                let r = bench_with_units(
                    &format!("simd_{id} α={alpha} threads={threads_max}"),
                    cfg,
                    work,
                    || {
                        let _ = kernel.run(&ops, &x, mask, &mut ctx, &mut out);
                    },
                );
                simd_sweep.push(KernelSweepRow {
                    kernel: id.as_str().to_string(),
                    alpha,
                    median_s: r.time.median,
                    flops: work,
                });
            }
        }
    }

    // --- float vs int8 kernels: the accuracy-vs-throughput frontier ------
    // The quant_sweep column: dense/masked raced against their int8
    // counterparts at the layer shape, always over the full builtin
    // registry (like simd_sweep, a `--kernels` restriction narrows routing,
    // not this comparison). Each row records what the int8 speed costs —
    // the full-rank quantized estimator's mask agreement against the float
    // estimator, and the worst-case logit ULP drift vs the same-work float
    // kernel — and the `argmin_winner` flag marks the cell's measured-cost
    // winner: the frontier the int8 kernels must actually appear on before
    // an operator has any reason to allow-list them.
    let mut quant_sweep = Vec::new();
    {
        let builtin = KernelRegistry::builtin();
        let pool = ThreadPool::new(threads_max);
        let mut ctx = ExecCtx::full(&pool);
        let layer = MaskedLayer::new(&b, &bias);
        let quant = QuantizedLayer::new(&layer.wt, &layer.bias);
        let ops = LayerOperands::new(&b, &layer).with_quant(&quant);
        // Full-rank estimator over the layer weights: quantizing the
        // factors must leave the predicted mask (the frontier's accuracy
        // axis) essentially unmoved.
        let mut est = SignEstimator::fit(&b, &bias, dim, 0.0);
        let mut float_mask = Mat::zeros(batch, dim);
        est.mask_into(&x, &mut float_mask);
        est.quantize_factors();
        let mut quant_mask = Mat::zeros(batch, dim);
        est.mask_into(&x, &mut quant_mask);
        let agree = float_mask
            .as_slice()
            .iter()
            .zip(quant_mask.as_slice())
            .filter(|(f, q)| f == q)
            .count();
        let mask_agreement = agree as f64 / float_mask.as_slice().len().max(1) as f64;

        let quant_ids =
            [KernelId::DENSE, KernelId::DENSE_I8, KernelId::MASKED, KernelId::MASKED_I8];
        let mut dense_want = Mat::zeros(batch, dim);
        let mut masked_want = Mat::zeros(batch, dim);
        for &(alpha, ref mask) in &masks {
            // Same-work float references for the drift axis at this mask.
            let _ = builtin
                .get(KernelId::DENSE)
                .expect("builtin dense")
                .run(&ops, &x, mask, &mut ctx, &mut dense_want);
            let _ = builtin
                .get(KernelId::MASKED)
                .expect("builtin masked")
                .run(&ops, &x, mask, &mut ctx, &mut masked_want);
            let mut cell = Vec::with_capacity(quant_ids.len());
            for id in quant_ids {
                let kernel = builtin.get(id).expect("builtin kernel");
                let work = if id.work().scales_with_alpha() {
                    layer_flops * alpha
                } else {
                    layer_flops
                };
                let r = bench_with_units(
                    &format!("quant_{id} α={alpha} threads={threads_max}"),
                    cfg,
                    work,
                    || {
                        let _ = kernel.run(&ops, &x, mask, &mut ctx, &mut out);
                    },
                );
                // `out` holds the kernel's last forward; drift is measured
                // against the same-work float reference (identically zero
                // for the float rows — deterministic kernels reproduce
                // their own reference bitwise).
                let want =
                    if id.work().scales_with_alpha() { &masked_want } else { &dense_want };
                let is_i8 = id == KernelId::DENSE_I8 || id == KernelId::MASKED_I8;
                cell.push(QuantSweepRow {
                    kernel: id.as_str().to_string(),
                    alpha,
                    median_s: r.time.median,
                    flops: work,
                    mask_agreement: if is_i8 { mask_agreement } else { 1.0 },
                    ulp_drift: drift_ulps_outside_band(&out, want),
                    argmin_winner: false,
                });
            }
            // The frontier verdict: measured-wall-clock argmin over the
            // four; strict `<` keeps the earlier (canonical-priority) row
            // on exact ties, matching dispatch's tie-break direction.
            let mut best = 0usize;
            for i in 1..cell.len() {
                if cell[i].median_s < cell[best].median_s {
                    best = i;
                }
            }
            cell[best].argmin_winner = true;
            quant_sweep.extend(cell);
        }
    }

    // Per-layer thresholds: the global ratio above is for *one* shape; each
    // hidden layer's d×h gets its own fit through the autotune harness
    // (quick budget — `condcomp calibrate` is the configurable-budget run),
    // one cost column per allowed kernel.
    let tuner = Autotuner {
        budget_ms: ((cfg.measure_s * 1000.0) as u64).clamp(40, 1000),
        alpha_grid: ALPHA_GRID.to_vec(),
        batch,
        min_reps: 1,
        fit_serial: true,
        kernels: registry.ids(),
    };
    let per_layer = if layer_sizes.len() >= 3 {
        let pool = ThreadPool::new(threads_max);
        tuner.calibrate_model_on(layer_sizes, &pool, &registry).layers
    } else {
        Vec::new()
    };

    // --- serving throughput vs batcher shard count ----------------------
    // Loopback arm: a real Server + concurrent TCP clients per shard count,
    // so the JSON records whether sharding the batcher moves end-to-end
    // request throughput (it should, on a multi-core runner; on one core
    // the column documents the overhead instead). Each shard count is
    // measured twice — leased executors (production) and the PR-3
    // private-pool baseline — so `serve_lease_vs_private` pins that pool
    // slicing does not regress throughput while spawning half the threads.
    let mut shard_counts = vec![1usize, 2, threads_max];
    shard_counts.sort_unstable();
    shard_counts.dedup();
    let requests_per_client = if cfg.measure_s < 0.2 { 5 } else { 25 };
    let mut shard_sweep = Vec::new();
    let mut lease_vs_private = Vec::new();
    for shards in shard_counts {
        let leased =
            measure_shard_throughput(shards, 4, requests_per_client, PoolMode::Lease);
        // At shards = 1 the PR-3 baseline also ran on the shared pool (it
        // never spawned a private pool for a single shard), so the two arms
        // are identical by construction and the ratio documents parity
        // noise; the informative rows are shards > 1.
        let private =
            measure_shard_throughput(shards, 4, requests_per_client, PoolMode::PrivatePools);
        lease_vs_private.push(LeaseVsPrivateRow {
            shards,
            clients: leased.clients,
            rps_lease: leased.rps,
            rps_private: private.rps,
        });
        shard_sweep.push(leased);
    }

    // --- multi-process serving arm ---------------------------------------
    // Workers are in-process single-shard Servers sharing one deterministic
    // backend; a coordinator fronts them through a fingerprint-verified
    // RemoteBackend, so the column measures the wire + replica-routing
    // overhead of N-process serving against the same model.
    let mut replica_sweep = Vec::new();
    for workers in [1usize, 2] {
        replica_sweep.push(measure_replica_throughput(workers, 4, requests_per_client));
    }

    // --- tracing off vs on ----------------------------------------------
    // Same loopback harness, one shard count, with the process-wide trace
    // flag flipped between arms (restored afterwards so a bench run never
    // leaves tracing on behind the operator's back).
    let trace_shards = 2.min(threads_max.max(1));
    let was_tracing = crate::trace::enabled();
    crate::trace::set_enabled(false);
    let off = measure_shard_throughput(trace_shards, 4, requests_per_client, PoolMode::Lease);
    crate::trace::set_enabled(true);
    let on = measure_shard_throughput(trace_shards, 4, requests_per_client, PoolMode::Lease);
    crate::trace::set_enabled(was_tracing);
    let trace_overhead = TraceOverheadRow {
        shards: trace_shards,
        clients: off.clients,
        rps_off: off.rps,
        rps_on: on.rps,
    };

    // --- bounded admission under offered overload ------------------------
    // Saturation is what the unthrottled loopback arm just measured at the
    // same server shape; each overload arm then offers a fixed multiple of
    // it against a server with a small per-shard queue bound, elastic
    // dispatch off and on.
    let saturation_rps = off.rps.max(1.0);
    let mut overload_sweep = Vec::new();
    for elastic_on in [false, true] {
        for &mult in &OVERLOAD_GRID {
            overload_sweep.push(measure_overload_arm(
                mult,
                elastic_on,
                saturation_rps,
                requests_per_client,
            ));
        }
    }

    ParallelSweep {
        dim,
        batch,
        threads_max,
        rows,
        dense_parallel_speedup,
        measured_cost_ratio,
        density_threshold: policy.density_threshold(),
        per_layer,
        kernel_sweep,
        simd_sweep,
        quant_sweep,
        shard_sweep,
        replica_sweep,
        lease_vs_private,
        trace_overhead,
        overload_sweep,
    }
}

/// Drive a bounded-admission server at `offered_x` times the measured
/// saturation throughput. Clients pipeline (send on a fixed interval
/// without waiting for replies), so offered load genuinely exceeds what
/// blocking round-trip clients could generate; every request still gets
/// exactly one reply — logits or an explicit overloaded shed — which is
/// what makes the accepted/shed accounting exact.
fn measure_overload_arm(
    offered_x: f64,
    elastic: bool,
    saturation_rps: f64,
    per_client: usize,
) -> OverloadRow {
    use std::io::{BufRead, BufReader, Write};
    let clients = 4usize;
    let mut rng = Pcg32::seeded(0x0E71);
    let net = Mlp::init(
        &NetConfig { layers: vec![24, 32, 24, 8], weight_sigma: 0.3, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 3);
    let backend = Arc::new(NativeBackend::new(net, est, 32));
    let server = Server::start(
        backend,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(1),
            shards: 2,
            max_queue_depth: 4,
            elastic,
            ..ServerConfig::default()
        },
    )
    .expect("overload server");
    let addr = server.local_addr;
    // Per-client send interval realizing the offered rate across all clients.
    let interval = clients as f64 / (saturation_rps * offered_x).max(1.0);
    let t0 = crate::util::Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = std::net::TcpStream::connect(addr).expect("loopback connect");
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
                let writer = stream;
                let sender = std::thread::spawn(move || {
                    let mut out = writer;
                    let mut rng = Pcg32::new(c as u64, 0x10AD);
                    for i in 0..per_client {
                        let req = Request::Predict {
                            id: i as u64 + 1,
                            mode: Mode::ConditionalAe,
                            x: Mat::randn(1, 24, 0.5, &mut rng),
                        };
                        let line = req.to_json_line();
                        out.write_all(line.as_bytes()).expect("send request");
                        out.write_all(b"\n").expect("send request");
                        out.flush().ok();
                        if interval > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(interval));
                        }
                    }
                });
                let mut accepted = 0usize;
                let mut shed = 0usize;
                let mut lat_us: Vec<f64> = Vec::new();
                for _ in 0..per_client {
                    let mut line = String::new();
                    if reader.read_line(&mut line).unwrap_or(0) == 0 {
                        break;
                    }
                    let resp = Response::parse(&line).expect("parse response");
                    if resp.overloaded {
                        shed += 1;
                    } else {
                        assert!(resp.ok, "unexpected error reply: {:?}", resp.error);
                        accepted += 1;
                        lat_us.push(resp.latency_us as f64);
                    }
                }
                sender.join().expect("sender thread");
                (accepted, shed, lat_us)
            })
        })
        .collect();
    let mut accepted = 0usize;
    let mut shed = 0usize;
    let mut lat_us: Vec<f64> = Vec::new();
    for h in handles {
        let (a, s, mut l) = h.join().expect("overload client");
        accepted += a;
        shed += s;
        lat_us.append(&mut l);
    }
    let elapsed_s = t0.elapsed_s().max(1e-9);
    server.shutdown();
    lat_us.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let p99_ms = if lat_us.is_empty() {
        0.0
    } else {
        lat_us[((lat_us.len() - 1) as f64 * 0.99) as usize] / 1e3
    };
    let offered = accepted + shed;
    OverloadRow {
        offered_x,
        elastic,
        offered_rps: offered as f64 / elapsed_s,
        accepted_rps: accepted as f64 / elapsed_s,
        shed_rate: shed as f64 / (offered as f64).max(1.0),
        p99_ms,
    }
}

/// Start a loopback server with `shards` batcher shards and drive it with
/// `clients` concurrent connections issuing single-row conditional predicts.
/// The model is a fixed small MLP — the point is coordinator scaling, not
/// kernel time, so layer work is kept light relative to queueing.
fn measure_shard_throughput(
    shards: usize,
    clients: usize,
    per_client: usize,
    pool_mode: PoolMode,
) -> ShardRow {
    let mut rng = Pcg32::seeded(0x5AD5);
    let net = Mlp::init(
        &NetConfig { layers: vec![24, 32, 24, 8], weight_sigma: 0.3, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 3);
    let backend = Arc::new(NativeBackend::new(net, est, 32));
    let server = Server::start(
        backend,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(1),
            shards,
            pool_mode,
            ..ServerConfig::default()
        },
    )
    .expect("shard-sweep server");
    let addr = server.local_addr;

    let t0 = crate::util::Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("loopback connect");
                let mut rng = Pcg32::new(c as u64, 0xBE);
                let mut done = 0usize;
                for _ in 0..per_client {
                    let x = Mat::randn(1, 24, 0.5, &mut rng);
                    let resp = client
                        .predict(x, crate::coordinator::protocol::Mode::ConditionalAe)
                        .expect("loopback predict");
                    assert!(resp.ok, "{:?}", resp.error);
                    done += 1;
                }
                done
            })
        })
        .collect();
    let requests: usize = handles.into_iter().map(|h| h.join().expect("client thread")).sum();
    let elapsed_s = t0.elapsed_s();
    server.shutdown();
    ShardRow {
        shards,
        clients,
        requests,
        elapsed_s,
        rps: requests as f64 / elapsed_s.max(1e-9),
    }
}

/// Start `workers` in-process single-shard worker servers over one shared
/// deterministic backend, front them with a coordinator server whose
/// backend is a [`RemoteBackend`], and drive the coordinator with `clients`
/// concurrent loopback connections. The model is the same fixed small MLP
/// as [`measure_shard_throughput`] — the point is coordinator/wire scaling,
/// not kernel time.
fn measure_replica_throughput(workers: usize, clients: usize, per_client: usize) -> ReplicaRow {
    use crate::coordinator::{Backend, RemoteBackend, RemoteOpts};
    let mut rng = Pcg32::seeded(0x5AD5);
    let net = Mlp::init(
        &NetConfig { layers: vec![24, 32, 24, 8], weight_sigma: 0.3, bias_init: 0.1 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6]), 3);
    let backend = Arc::new(NativeBackend::new(net, est, 32));
    let worker_servers: Vec<Server> = (0..workers)
        .map(|_| {
            Server::start(
                backend.clone(),
                ServerConfig {
                    addr: "127.0.0.1:0".into(),
                    max_wait: std::time::Duration::from_millis(1),
                    shards: 1,
                    ..ServerConfig::default()
                },
            )
            .expect("replica worker server")
        })
        .collect();
    let addrs: Vec<String> = worker_servers.iter().map(|s| s.local_addr.to_string()).collect();
    let expected = backend.model_fingerprint().unwrap_or_default();
    let remote = Arc::new(
        RemoteBackend::connect(
            &addrs,
            &expected,
            RemoteOpts {
                health_interval: std::time::Duration::from_millis(50),
                ..RemoteOpts::default()
            },
        )
        .expect("replica coordinator connects"),
    );
    let server = Server::start(
        remote.clone() as Arc<dyn Backend>,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(1),
            shards: 2,
            ..ServerConfig::default()
        },
    )
    .expect("replica coordinator server");
    let addr = server.local_addr;

    let t0 = crate::util::Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).expect("loopback connect");
                let mut rng = Pcg32::new(c as u64, 0xBE);
                let mut done = 0usize;
                for _ in 0..per_client {
                    let x = Mat::randn(1, 24, 0.5, &mut rng);
                    let resp = client
                        .predict(x, crate::coordinator::protocol::Mode::ConditionalAe)
                        .expect("loopback predict");
                    assert!(resp.ok, "{:?}", resp.error);
                    done += 1;
                }
                done
            })
        })
        .collect();
    let requests: usize = handles.into_iter().map(|h| h.join().expect("client thread")).sum();
    let elapsed_s = t0.elapsed_s();
    server.shutdown();
    drop(remote);
    for w in worker_servers {
        w.shutdown();
    }
    ReplicaRow {
        workers,
        clients,
        requests,
        elapsed_s,
        rps: requests as f64 / elapsed_s.max(1e-9),
    }
}

impl ParallelSweep {
    /// Human-readable report lines (the CLI prints these).
    pub fn report_lines(&self) -> Vec<String> {
        let mut lines = vec![
            format!(
                "parallel sweep: dim={} batch={} threads={{1,{}}}",
                self.dim, self.batch, self.threads_max
            ),
            format!(
                "{:<36} {:>8} {:>8} {:>12} {:>10}",
                "kernel", "threads", "alpha", "median", "GF/s"
            ),
        ];
        for row in &self.rows {
            let alpha = row
                .alpha
                .map(|a| format!("{a:.2}"))
                .unwrap_or_else(|| "-".to_string());
            lines.push(format!(
                "{:<36} {:>8} {:>8} {:>10.3}ms {:>10.2}",
                row.kernel,
                row.threads,
                alpha,
                row.median_s * 1e3,
                row.flops / row.median_s.max(1e-12) / 1e9
            ));
        }
        lines.push(format!(
            "dense {0}×{0}×{0} parallel speedup: {1:.2}× on {2} threads",
            self.dim, self.dense_parallel_speedup, self.threads_max
        ));
        lines.push(format!(
            "measured cost ratio {:.2} → dispatch flips masked→dense at α = {:.3}",
            self.measured_cost_ratio, self.density_threshold
        ));
        for lt in &self.per_layer {
            let cols: Vec<String> = lt
                .kernel_costs
                .iter()
                .map(|(k, v)| format!("{k}:{v:.2}"))
                .collect();
            lines.push(format!(
                "layer {} ({}×{}): cost ratio {:.2} → α* = {:.3}  [{}]",
                lt.layer,
                lt.d,
                lt.h,
                lt.cost_ratio,
                lt.alpha_star,
                cols.join(" ")
            ));
        }
        for row in &self.kernel_sweep {
            lines.push(format!(
                "kernel sweep: {:<14} α={:.2} → {:>9.3}ms  {:>8.2} GF/s",
                row.kernel,
                row.alpha,
                row.median_s * 1e3,
                row.flops / row.median_s.max(1e-12) / 1e9
            ));
        }
        for row in &self.simd_sweep {
            lines.push(format!(
                "simd sweep:   {:<14} α={:.2} → {:>9.3}ms  {:>8.2} GF/s",
                row.kernel,
                row.alpha,
                row.median_s * 1e3,
                row.flops / row.median_s.max(1e-12) / 1e9
            ));
        }
        for row in &self.quant_sweep {
            lines.push(format!(
                "quant sweep:  {:<14} α={:.2} → {:>9.3}ms  {:>8.2} GF/s  agree={:.4} drift={:.0}ulp{}",
                row.kernel,
                row.alpha,
                row.median_s * 1e3,
                row.flops / row.median_s.max(1e-12) / 1e9,
                row.mask_agreement,
                row.ulp_drift,
                if row.argmin_winner { "  ← argmin" } else { "" }
            ));
        }
        for row in &self.shard_sweep {
            lines.push(format!(
                "serve loopback: shards={} clients={} → {:.0} req/s ({} requests in {:.3}s)",
                row.shards, row.clients, row.rps, row.requests, row.elapsed_s
            ));
        }
        for row in &self.replica_sweep {
            lines.push(format!(
                "serve replicas: workers={} clients={} → {:.0} req/s ({} requests in {:.3}s)",
                row.workers, row.clients, row.rps, row.requests, row.elapsed_s
            ));
        }
        for row in &self.lease_vs_private {
            lines.push(format!(
                "serve lease-vs-private: shards={} → leased {:.0} req/s vs private {:.0} req/s ({:.2}×)",
                row.shards,
                row.rps_lease,
                row.rps_private,
                row.lease_over_private()
            ));
        }
        lines.push(format!(
            "serve trace overhead: shards={} → off {:.0} req/s vs on {:.0} req/s ({:.2}×)",
            self.trace_overhead.shards,
            self.trace_overhead.rps_off,
            self.trace_overhead.rps_on,
            self.trace_overhead.on_over_off()
        ));
        for row in &self.overload_sweep {
            lines.push(format!(
                "serve overload: {:.1}× offered (elastic {}) → accepted {:.0} req/s, shed {:.0}%, p99 {:.2}ms",
                row.offered_x,
                if row.elastic { "on" } else { "off" },
                row.accepted_rps,
                row.shed_rate * 100.0,
                row.p99_ms
            ));
        }
        lines
    }

    /// Machine-readable rendering (written to `BENCH_parallel.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::Num(self.dim as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("threads_max", Json::Num(self.threads_max as f64)),
            (
                "dense_parallel_speedup",
                Json::Num(self.dense_parallel_speedup),
            ),
            ("measured_cost_ratio", Json::Num(self.measured_cost_ratio)),
            ("density_threshold", Json::Num(self.density_threshold)),
            (
                "alpha_grid",
                Json::Arr(ALPHA_GRID.iter().map(|&a| Json::Num(a)).collect()),
            ),
            (
                "per_layer_thresholds",
                Json::Arr(self.per_layer.iter().map(LayerThreshold::to_json).collect()),
            ),
            (
                "kernel_sweep",
                Json::Arr(self.kernel_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "simd_sweep",
                Json::Arr(self.simd_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "quant_sweep",
                Json::Arr(self.quant_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "serve_shard_sweep",
                Json::Arr(self.shard_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "serve_replica_sweep",
                Json::Arr(self.replica_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "serve_lease_vs_private",
                Json::Arr(self.lease_vs_private.iter().map(|r| r.to_json()).collect()),
            ),
            ("trace_overhead", self.trace_overhead.to_json()),
            (
                "overload_sweep",
                Json::Arr(self.overload_sweep.iter().map(|r| r.to_json()).collect()),
            ),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny dims keep this test in the tens of milliseconds; it checks the
    /// sweep's *structure* (rows, JSON schema, threshold sanity), not perf.
    #[test]
    fn sweep_produces_complete_machine_readable_output() {
        // The sweep flips the process-wide trace flag for its overhead
        // column; serialize with other tests that touch the same flag.
        let _guard = crate::trace::test_lock();
        crate::trace::set_enabled(false);
        let cfg = BenchConfig { warmup_s: 0.0, measure_s: 0.0, min_iters: 1, max_iters: 1 };
        let layer_sizes = [24usize, 20, 16, 6];
        let sweep = run_parallel_sweep(&cfg, 32, 8, 2, &layer_sizes, None);
        // 2 dense_gemm + 2×(dense_gemm_batch + dense_forward + 4 masked) rows.
        assert_eq!(sweep.rows.len(), 2 + 2 * (2 + ALPHA_GRID.len()));
        assert!(sweep.measured_cost_ratio > 0.0 && sweep.measured_cost_ratio.is_finite());
        assert!((0.0..=1.0).contains(&sweep.density_threshold));
        assert!(!sweep.report_lines().is_empty());
        // Per-layer fits: one per hidden layer, each with a sane α* and one
        // cost column per registered kernel.
        assert_eq!(sweep.per_layer.len(), 2);
        let registry_ids = KernelRegistry::builtin().ids();
        for (l, lt) in sweep.per_layer.iter().enumerate() {
            assert_eq!((lt.layer, lt.d, lt.h), (l, layer_sizes[l], layer_sizes[l + 1]));
            assert!((0.0..=1.0).contains(&lt.alpha_star));
            assert_eq!(lt.kernel_costs.len(), registry_ids.len(), "{:?}", lt.kernel_costs);
        }
        // Kernel sweep: every registered kernel at every grid density.
        assert_eq!(sweep.kernel_sweep.len(), ALPHA_GRID.len() * registry_ids.len());
        for row in &sweep.kernel_sweep {
            assert!(row.median_s >= 0.0 && row.flops > 0.0, "{row:?}");
            assert!(registry_ids.iter().any(|k| k.as_str() == row.kernel));
        }
        // SIMD sweep: the fixed five-way race at every grid density.
        let simd_ids = ["dense", "dense_packed", "dense_simd", "masked", "masked_simd"];
        assert_eq!(sweep.simd_sweep.len(), ALPHA_GRID.len() * simd_ids.len());
        for id in simd_ids {
            assert_eq!(
                sweep.simd_sweep.iter().filter(|r| r.kernel == id).count(),
                ALPHA_GRID.len(),
                "{id} measured once per α"
            );
        }
        // Quant sweep: the fixed four-way frontier at every grid density —
        // exactly one argmin winner per cell, float rows bit-exact against
        // themselves (zero drift, full agreement), int8 rows carrying the
        // full-rank estimator agreement and a finite drift.
        let quant_ids = ["dense", "dense_i8", "masked", "masked_i8"];
        assert_eq!(sweep.quant_sweep.len(), ALPHA_GRID.len() * quant_ids.len());
        for &alpha in &ALPHA_GRID {
            let cell: Vec<_> =
                sweep.quant_sweep.iter().filter(|r| r.alpha == alpha).collect();
            assert_eq!(cell.len(), quant_ids.len());
            assert_eq!(
                cell.iter().filter(|r| r.argmin_winner).count(),
                1,
                "one argmin winner at α={alpha}"
            );
            for row in cell {
                assert!(row.median_s >= 0.0 && row.flops > 0.0, "{row:?}");
                assert!((0.0..=1.0).contains(&row.mask_agreement), "{row:?}");
                assert!(row.ulp_drift >= 0.0 && row.ulp_drift.is_finite(), "{row:?}");
                if row.kernel == "dense" || row.kernel == "masked" {
                    assert_eq!(row.mask_agreement, 1.0, "{row:?}");
                    assert_eq!(row.ulp_drift, 0.0, "float rows are their own reference");
                }
            }
        }
        // The full-rank quantized estimator's *raw* agreement (every entry,
        // including the near-zero band where sign flips are cheap) stays
        // high even at this tiny shape. The ≥ 0.99 tier floor is a
        // band-excluded contract, enforced by the estimator property tests.
        let i8_agreement = sweep
            .quant_sweep
            .iter()
            .find(|r| r.kernel == "dense_i8")
            .expect("dense_i8 row")
            .mask_agreement;
        assert!(i8_agreement >= 0.9, "full-rank quantized mask agreement {i8_agreement}");

        // Shard column: {1, 2, threads_max=2} dedups to {1, 2}; every row
        // completed all of its requests.
        assert_eq!(
            sweep.shard_sweep.iter().map(|r| r.shards).collect::<Vec<_>>(),
            vec![1, 2]
        );
        for row in &sweep.shard_sweep {
            assert_eq!(row.requests, row.clients * 5, "quick run: 5 requests per client");
            assert!(row.rps > 0.0 && row.rps.is_finite());
        }
        // Replica column: coordinator over {1, 2} in-process worker
        // servers; every row completed all of its requests.
        assert_eq!(
            sweep.replica_sweep.iter().map(|r| r.workers).collect::<Vec<_>>(),
            vec![1, 2]
        );
        for row in &sweep.replica_sweep {
            assert_eq!(row.requests, row.clients * 5, "quick run: 5 requests per client");
            assert!(row.rps > 0.0 && row.rps.is_finite());
        }
        // Lease-vs-private column: both arms measured at every shard count.
        assert_eq!(
            sweep.lease_vs_private.iter().map(|r| r.shards).collect::<Vec<_>>(),
            vec![1, 2]
        );
        for row in &sweep.lease_vs_private {
            assert!(row.rps_lease > 0.0 && row.rps_lease.is_finite());
            assert!(row.rps_private > 0.0 && row.rps_private.is_finite());
            assert!(row.lease_over_private() > 0.0);
        }
        // Trace-overhead column: both arms measured, flag restored.
        assert_eq!(sweep.trace_overhead.shards, 2);
        assert!(sweep.trace_overhead.rps_off > 0.0 && sweep.trace_overhead.rps_off.is_finite());
        assert!(sweep.trace_overhead.rps_on > 0.0 && sweep.trace_overhead.rps_on.is_finite());
        assert!(sweep.trace_overhead.on_over_off() > 0.0);
        assert!(!crate::trace::enabled(), "sweep must restore the trace flag");
        // Overload column: every offered multiple × elastic arm measured;
        // accounting is exact (accepted + shed == offered ⇒ shed_rate ≤ 1).
        assert_eq!(sweep.overload_sweep.len(), 2 * OVERLOAD_GRID.len());
        for (i, row) in sweep.overload_sweep.iter().enumerate() {
            assert_eq!(row.elastic, i >= OVERLOAD_GRID.len());
            assert_eq!(row.offered_x, OVERLOAD_GRID[i % OVERLOAD_GRID.len()]);
            assert!(row.offered_rps > 0.0 && row.offered_rps.is_finite());
            assert!(row.accepted_rps >= 0.0 && row.accepted_rps.is_finite());
            assert!((0.0..=1.0).contains(&row.shed_rate), "{row:?}");
            assert!(row.p99_ms >= 0.0 && row.p99_ms.is_finite());
        }

        let json = sweep.to_json();
        let parsed = Json::parse(&json.to_string()).expect("self-parse");
        assert!(parsed.get("density_threshold").and_then(|v| v.as_f64()).is_some());
        let kernel_rows = parsed
            .get("kernel_sweep")
            .and_then(|v| v.as_arr())
            .expect("kernel_sweep column");
        assert_eq!(kernel_rows.len(), sweep.kernel_sweep.len());
        for id in &registry_ids {
            assert!(
                kernel_rows
                    .iter()
                    .any(|r| r.get("kernel").and_then(|k| k.as_str()) == Some(id.as_str())),
                "kernel {id} missing from kernel_sweep JSON"
            );
        }
        assert!(kernel_rows
            .iter()
            .all(|r| r.get("alpha").is_some() && r.get("gflops_per_s").is_some()));
        let simd_rows = parsed
            .get("simd_sweep")
            .and_then(|v| v.as_arr())
            .expect("simd_sweep column");
        assert_eq!(simd_rows.len(), sweep.simd_sweep.len());
        for id in simd_ids {
            assert!(
                simd_rows
                    .iter()
                    .any(|r| r.get("kernel").and_then(|k| k.as_str()) == Some(id)),
                "kernel {id} missing from simd_sweep JSON"
            );
        }
        let quant_rows = parsed
            .get("quant_sweep")
            .and_then(|v| v.as_arr())
            .expect("quant_sweep column");
        assert_eq!(quant_rows.len(), sweep.quant_sweep.len());
        for id in quant_ids {
            assert!(
                quant_rows
                    .iter()
                    .any(|r| r.get("kernel").and_then(|k| k.as_str()) == Some(id)),
                "kernel {id} missing from quant_sweep JSON"
            );
        }
        assert!(quant_rows.iter().all(|r| {
            r.get("alpha").is_some()
                && r.get("gflops_per_s").is_some()
                && r.get("mask_agreement").and_then(|v| v.as_f64()).is_some()
                && r.get("ulp_drift").and_then(|v| v.as_f64()).is_some()
                && r.get("argmin_winner").and_then(|v| v.as_bool()).is_some()
        }));
        let shard_rows = parsed
            .get("serve_shard_sweep")
            .and_then(|v| v.as_arr())
            .expect("serve_shard_sweep");
        assert_eq!(shard_rows.len(), 2);
        assert!(shard_rows.iter().all(|r| r.get("shards").is_some() && r.get("rps").is_some()));
        let replica_rows = parsed
            .get("serve_replica_sweep")
            .and_then(|v| v.as_arr())
            .expect("serve_replica_sweep");
        assert_eq!(replica_rows.len(), 2);
        assert!(replica_rows
            .iter()
            .all(|r| r.get("workers").is_some() && r.get("rps").is_some()));
        let lvp_rows = parsed
            .get("serve_lease_vs_private")
            .and_then(|v| v.as_arr())
            .expect("serve_lease_vs_private");
        assert_eq!(lvp_rows.len(), 2);
        assert!(lvp_rows
            .iter()
            .all(|r| r.get("rps_lease").is_some() && r.get("rps_private").is_some()));
        let trace_row = parsed.get("trace_overhead").expect("trace_overhead");
        assert!(trace_row.get("rps_off").and_then(|v| v.as_f64()).is_some());
        assert!(trace_row.get("rps_on").and_then(|v| v.as_f64()).is_some());
        assert!(trace_row.get("on_over_off").and_then(|v| v.as_f64()).is_some());
        let overload_rows = parsed
            .get("overload_sweep")
            .and_then(|v| v.as_arr())
            .expect("overload_sweep column");
        assert_eq!(overload_rows.len(), sweep.overload_sweep.len());
        assert!(overload_rows.iter().all(|r| {
            r.get("offered_x").is_some()
                && r.get("elastic").and_then(|e| e.as_bool()).is_some()
                && r.get("accepted_rps").is_some()
                && r.get("shed_rate").is_some()
                && r.get("p99_ms").is_some()
        }));
        let per_layer = parsed
            .get("per_layer_thresholds")
            .and_then(|v| v.as_arr())
            .expect("per_layer_thresholds");
        assert_eq!(per_layer.len(), 2);
        assert!(per_layer.iter().all(|r| r.get("alpha_star").is_some()));
        let rows = parsed.get("rows").and_then(|v| v.as_arr()).expect("rows");
        assert_eq!(rows.len(), sweep.rows.len());
        assert!(rows.iter().all(|r| r.get("median_s").is_some()));
        // Masked rows carry their α.
        assert!(rows
            .iter()
            .filter(|r| r.get("kernel").and_then(|k| k.as_str()) == Some("masked_forward"))
            .all(|r| r.get("alpha").is_some()));
    }
}
