//! The execution-context subsystem: one handle for pool, arena, policy, and
//! metrics across the whole stack.
//!
//! Three PRs of growth left execution state threaded by hand —
//! `Backend::predict_on(x, mode, pool, arena)` carried a raw pool and a
//! scratch arena, the dispatch `PolicyTable` hid behind the backend's lock,
//! and metrics scoping was a separate side channel. [`ExecCtx`] bundles all
//! four behind one borrowed handle:
//!
//! - a [`crate::parallel::PoolLease`] — which slice of the shared worker
//!   pool this caller occupies (the serving coordinator leases each shard's
//!   slice from the global pool, so N shards cost exactly the configured
//!   thread budget);
//! - a [`ScratchArena`] — recycled activation buffers (moved here from the
//!   coordinator; it was never serving-specific);
//! - an optional pinned read view of the
//!   [`crate::condcomp::PolicyTable`] — tests and calibration force a
//!   kernel choice; backends otherwise snapshot their live table;
//! - an optional pinned [`crate::condcomp::KernelRegistry`] view — which
//!   compute kernels the cost router may pick from (the multi-kernel
//!   counterpart of the policy view);
//! - a [`MetricsScope`] — per-shard metrics without threading a registry
//!   and shard index separately.
//!
//! Consumers: `Backend::predict_ctx` is the serving entry point; the
//! condcomp kernels expose `*_ctx` variants (`forward_masked_ctx`,
//! `mask_ctx`, `matmul_into_ctx`, …) that chunk by the ctx's lease width;
//! the autotune harness measures through a ctx so calibration exercises the
//! same code path it tunes. The invariant carried over from `parallel/`:
//! **results never depend on the ctx** — lease width, arena state and
//! metrics scope change wall-clock and observability only.

pub mod arena;
pub mod ctx;

pub use arena::ScratchArena;
pub use ctx::{ExecCtx, MetricsScope};
