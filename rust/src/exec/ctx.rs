//! The execution context: one handle for pool lease, scratch arena, policy
//! view, and metrics scope.

use super::arena::ScratchArena;
use crate::condcomp::{ElasticConfig, KernelRegistry, PolicyTable};
use crate::coordinator::metrics::{MetricsRegistry, ShardSink};
use crate::parallel::{PoolLease, ThreadPool};
use crate::trace::{Span, SpanCollector};
use std::sync::Arc;
use std::time::Instant;

/// Where a context's metrics land: nowhere (tests, CLI one-shots), a shared
/// registry, or a shard-scoped view of one. A shard scope caches its
/// [`ShardSink`] stripe at construction, so hot-path writes take the
/// stripe's uncontended lock under *plain* names — the registry's snapshot
/// materializes the fleet total and the `shard<i>_` breakdown from the same
/// write, with no per-call key formatting.
///
/// The scope is also where spans come from: [`MetricsScope::span`] returns
/// a guard that, when tracing is enabled ([`crate::trace::enabled`]), times
/// its scope into the `span_<name>` latency series and — if a
/// [`SpanCollector`] is attached ([`MetricsScope::with_spans`], shard
/// executors do) — into the per-batch span list the flight recorder keeps.
/// With tracing off the guard is inert: one relaxed atomic load, no clock
/// reads, no allocation.
#[derive(Clone, Default)]
pub struct MetricsScope {
    registry: Option<Arc<MetricsRegistry>>,
    shard: Option<usize>,
    sink: Option<Arc<ShardSink>>,
    spans: Option<Arc<SpanCollector>>,
}

impl MetricsScope {
    /// No-op scope: every write is dropped.
    pub fn none() -> MetricsScope {
        MetricsScope::default()
    }

    /// Global scope: writes land in the registry's global sink.
    pub fn global(registry: Arc<MetricsRegistry>) -> MetricsScope {
        MetricsScope { registry: Some(registry), shard: None, sink: None, spans: None }
    }

    /// Shard scope: writes land in the shard's stripe (read back under both
    /// the plain and the `shard<i>_` key).
    pub fn for_shard(registry: Arc<MetricsRegistry>, shard: usize) -> MetricsScope {
        let sink = registry.shard_sink(shard);
        MetricsScope { registry: Some(registry), shard: Some(shard), sink: Some(sink), spans: None }
    }

    /// Attach a per-batch span collector (shard executors, so the flight
    /// recorder can keep each batch's span breakdown).
    pub fn with_spans(mut self, spans: Arc<SpanCollector>) -> MetricsScope {
        self.spans = Some(spans);
        self
    }

    /// The shard this scope is pinned to, if any.
    pub fn shard(&self) -> Option<usize> {
        self.shard
    }

    /// The backing registry, if any (for writes that must stay global-only,
    /// e.g. cross-shard totals the caller aggregates itself).
    pub fn registry(&self) -> Option<&MetricsRegistry> {
        self.registry.as_deref()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, by: u64) {
        if let Some(sink) = &self.sink {
            sink.add(name, by);
        } else if let Some(reg) = &self.registry {
            reg.add(name, by);
        }
    }

    pub fn set_gauge(&self, name: &str, value: f64) {
        if let Some(sink) = &self.sink {
            sink.set_gauge(name, value);
        } else if let Some(reg) = &self.registry {
            reg.set_gauge(name, value);
        }
    }

    pub fn observe_latency(&self, name: &str, seconds: f64) {
        if let Some(sink) = &self.sink {
            sink.observe(name, seconds);
        } else if let Some(reg) = &self.registry {
            reg.observe_latency(name, seconds);
        }
    }

    /// Open a timed span (`recv`, `estimator`, `reply`, …). Returns an
    /// inert guard unless tracing is enabled and this scope has somewhere
    /// to record.
    pub fn span(&self, name: &'static str) -> SpanGuard {
        self.span_with(name, None)
    }

    /// Open a timed span with a static detail suffix — the kernel spans use
    /// the chosen [`crate::condcomp::KernelId`] (`kernel_masked_simd`).
    pub fn span_with(&self, name: &'static str, detail: Option<&'static str>) -> SpanGuard {
        if !crate::trace::enabled() || self.registry.is_none() {
            return SpanGuard { inner: None };
        }
        SpanGuard {
            inner: Some(SpanGuardInner {
                name,
                detail,
                registry: self.registry.clone(),
                sink: self.sink.clone(),
                spans: self.spans.clone(),
                start: Instant::now(),
            }),
        }
    }

    /// Take the spans collected since the last drain (empty when no
    /// collector is attached). Shard executors call this once per batch to
    /// build the flight record.
    pub fn drain_spans(&self) -> Vec<Span> {
        self.spans.as_ref().map(|c| c.drain()).unwrap_or_default()
    }
}

/// RAII span: times from creation to drop, then records into the scope's
/// `span_<label>` latency series and (if attached) the span collector. The
/// guard owns cloned `Arc`s, so it can outlive borrows of the scope that
/// issued it — open a span, then keep using `&mut ExecCtx` freely.
pub struct SpanGuard {
    inner: Option<SpanGuardInner>,
}

struct SpanGuardInner {
    name: &'static str,
    detail: Option<&'static str>,
    registry: Option<Arc<MetricsRegistry>>,
    sink: Option<Arc<ShardSink>>,
    spans: Option<Arc<SpanCollector>>,
    start: Instant,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(g) = self.inner.take() else { return };
        let seconds = g.start.elapsed().as_secs_f64();
        let span = Span { name: g.name, detail: g.detail, micros: seconds * 1e6 };
        let series = format!("span_{}", span.label());
        if let Some(sink) = &g.sink {
            sink.observe(&series, seconds);
        } else if let Some(reg) = &g.registry {
            reg.observe_latency(&series, seconds);
        }
        if let Some(collector) = &g.spans {
            collector.push(span);
        }
    }
}

/// One borrowed handle for everything a forward pass executes with:
///
/// - a [`PoolLease`] — which slice of the shared worker pool this caller
///   may occupy (the kernels' ctx entry points chunk by its width);
/// - a [`ScratchArena`] — recycled activation buffers, owned by the ctx so
///   the per-batch path takes no lock;
/// - an optional pinned [`PolicyTable`] — a read view of the dispatch
///   policy; when unset, backends snapshot their own live table per batch,
///   and tests/calibration pin one to force a kernel choice;
/// - an optional pinned [`KernelRegistry`] view — which compute kernels the
///   cost router may pick from; when unset, backends use their own
///   (possibly allow-list-restricted) registry, and tests/calibration pin
///   one to measure a specific kernel;
/// - a [`MetricsScope`] — where execution metrics land (per-shard on the
///   serving path, nowhere for CLI one-shots).
///
/// The ctx is long-lived: a shard executor builds one at startup and
/// threads `&mut ExecCtx` through every batch, so arena buffers recycle
/// across batches and the lease is held for the executor's lifetime.
/// Results never depend on the ctx (lease width, arena state, metrics) —
/// only the pinned policy/registry can change *which* kernel runs, and any
/// two kernels sharing a work model agree within their declared
/// [`crate::condcomp::EquivalenceTier`] (bit-exact for the scalar kernels,
/// ULP-bounded for the SIMD ones).
pub struct ExecCtx<'p> {
    lease: PoolLease<'p>,
    arena: ScratchArena,
    policy: Option<PolicyTable>,
    registry: Option<Arc<KernelRegistry>>,
    metrics: MetricsScope,
    /// The owning shard's queue pressure in `[0, 1]` (0.0 = calm /
    /// unbounded). Executors refresh it per batch; backends read it for
    /// quality-elastic dispatch.
    pressure: f64,
    /// Elastic-degradation knobs; `None` = elastic dispatch off (the
    /// default — pressure is then observational only).
    elastic: Option<ElasticConfig>,
}

impl<'p> ExecCtx<'p> {
    /// Ctx over an explicit lease, with a fresh arena and no metrics.
    pub fn over(lease: PoolLease<'p>) -> ExecCtx<'p> {
        ExecCtx {
            lease,
            arena: ScratchArena::new(),
            policy: None,
            registry: None,
            metrics: MetricsScope::none(),
            pressure: 0.0,
            elastic: None,
        }
    }

    /// Ctx over a *reserving* full-pool lease (granted whatever capacity is
    /// free). The startup-calibration path uses this so warm-up exercises
    /// exactly the leased code path serving will run.
    pub fn full(pool: &'p ThreadPool) -> ExecCtx<'p> {
        ExecCtx::over(pool.lease(pool.threads()))
    }

    /// Ctx over a non-reserving shared view of the pool: full width, no
    /// slots subtracted from the leasable capacity. The compatibility path
    /// for pool-less callers ([`crate::coordinator::Backend::predict`]).
    pub fn shared(pool: &'p ThreadPool) -> ExecCtx<'p> {
        ExecCtx::over(pool.share())
    }

    /// Replace the arena (e.g. with recycled buffers from a shared pool).
    pub fn with_arena(mut self, arena: ScratchArena) -> ExecCtx<'p> {
        self.arena = arena;
        self
    }

    /// Pin a dispatch-policy table: backends use it instead of their own
    /// live table, so the caller controls the kernel choice.
    pub fn with_policy(mut self, table: PolicyTable) -> ExecCtx<'p> {
        self.policy = Some(table);
        self
    }

    /// Pin or clear the dispatch-policy table in place (backends pin a
    /// snapshot around a forward and restore afterwards, so a long-lived
    /// shard ctx never freezes out recalibration).
    pub fn set_policy(&mut self, table: Option<PolicyTable>) {
        self.policy = table;
    }

    /// Pin a kernel-registry view: the cost router picks only from these
    /// kernels (tests and calibration measure one kernel by pinning a
    /// singleton registry).
    pub fn with_registry(mut self, registry: Arc<KernelRegistry>) -> ExecCtx<'p> {
        self.registry = Some(registry);
        self
    }

    /// Pin or clear the registry view in place.
    pub fn set_registry(&mut self, registry: Option<Arc<KernelRegistry>>) {
        self.registry = registry;
    }

    /// The pinned kernel-registry view, if any.
    pub fn registry(&self) -> Option<&Arc<KernelRegistry>> {
        self.registry.as_ref()
    }

    /// Attach a metrics scope.
    pub fn with_metrics(mut self, metrics: MetricsScope) -> ExecCtx<'p> {
        self.metrics = metrics;
        self
    }

    /// Enable quality-elastic dispatch with these degradation knobs (shard
    /// executors, when `server.elastic` is on).
    pub fn with_elastic(mut self, elastic: ElasticConfig) -> ExecCtx<'p> {
        self.elastic = Some(elastic);
        self
    }

    /// The elastic knobs, if elastic dispatch is enabled on this ctx.
    pub fn elastic(&self) -> Option<&ElasticConfig> {
        self.elastic.as_ref()
    }

    /// Refresh the queue-pressure view (clamped to `[0, 1]`; NaN → 0).
    /// Executors call this once per batch before `predict_ctx`.
    pub fn set_pressure(&mut self, pressure: f64) {
        self.pressure = if pressure.is_finite() { pressure.clamp(0.0, 1.0) } else { 0.0 };
    }

    /// The owning shard's queue pressure in `[0, 1]`.
    pub fn pressure(&self) -> f64 {
        self.pressure
    }

    /// The pool slice this ctx executes on.
    pub fn lease(&self) -> &PoolLease<'p> {
        &self.lease
    }

    /// Effective worker count (the lease width; `1` = inline).
    pub fn threads(&self) -> usize {
        self.lease.threads()
    }

    /// The recycled-buffer arena.
    pub fn arena(&mut self) -> &mut ScratchArena {
        &mut self.arena
    }

    /// Take a buffer of exactly `len` elements from the arena.
    pub fn take_buf(&mut self, len: usize) -> Vec<f32> {
        self.arena.take(len)
    }

    /// Return a buffer to the arena for reuse.
    pub fn put_buf(&mut self, buf: Vec<f32>) {
        self.arena.put(buf);
    }

    /// The pinned policy table, if any.
    pub fn policy(&self) -> Option<&PolicyTable> {
        self.policy.as_ref()
    }

    /// Where this ctx's execution metrics land.
    pub fn metrics(&self) -> &MetricsScope {
        &self.metrics
    }

    /// Tear down, returning the arena (shared-arena callers hand their
    /// buffers back this way). Drops the lease, releasing its reservation.
    pub fn into_arena(self) -> ScratchArena {
        self.arena
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::ThreadPool;

    #[test]
    fn ctx_carries_lease_width_and_recycles_buffers() {
        let pool = ThreadPool::new(4);
        let mut ctx = ExecCtx::over(pool.lease(2));
        assert_eq!(ctx.threads(), 2);
        assert_eq!(pool.leased(), 2);
        let buf = ctx.take_buf(16);
        assert_eq!(buf.len(), 16);
        ctx.put_buf(buf);
        assert_eq!(ctx.arena().len(), 1);
        let arena = ctx.into_arena();
        assert_eq!(arena.len(), 1);
        assert_eq!(pool.leased(), 0, "into_arena drops the lease");
    }

    #[test]
    fn pressure_and_elastic_views_default_off_and_clamp() {
        let pool = ThreadPool::new(2);
        let mut ctx = ExecCtx::over(pool.lease(1));
        assert_eq!(ctx.pressure(), 0.0);
        assert!(ctx.elastic().is_none(), "elastic dispatch is opt-in");
        ctx.set_pressure(0.6);
        assert_eq!(ctx.pressure(), 0.6);
        ctx.set_pressure(7.0);
        assert_eq!(ctx.pressure(), 1.0, "clamped to [0, 1]");
        ctx.set_pressure(-1.0);
        assert_eq!(ctx.pressure(), 0.0);
        ctx.set_pressure(f64::NAN);
        assert_eq!(ctx.pressure(), 0.0, "NaN is treated as calm");
        let ctx = ctx.with_elastic(crate::condcomp::ElasticConfig::default());
        let e = ctx.elastic().expect("elastic knobs attached");
        assert_eq!(e.pressure_threshold, 0.75);
    }

    #[test]
    fn full_reserves_and_shared_does_not() {
        let pool = ThreadPool::new(3);
        {
            let ctx = ExecCtx::full(&pool);
            assert_eq!(ctx.threads(), 3);
            assert_eq!(pool.leased(), 3);
        }
        assert_eq!(pool.leased(), 0);
        let ctx = ExecCtx::shared(&pool);
        assert_eq!(ctx.threads(), 3);
        assert_eq!(pool.leased(), 0);
    }

    #[test]
    fn shard_scope_mirrors_writes_under_both_keys() {
        let reg = Arc::new(MetricsRegistry::new());
        let scope = MetricsScope::for_shard(reg.clone(), 2);
        scope.incr("batches");
        scope.add("rows", 5);
        scope.set_gauge("speedup", 1.5);
        scope.observe_latency("predict", 0.25);
        assert_eq!(reg.counter("batches"), 1);
        assert_eq!(reg.shard_counter(2, "batches"), 1);
        assert_eq!(reg.counter("shard2_rows"), 5);
        assert_eq!(reg.gauge("speedup"), Some(1.5));
        assert_eq!(reg.shard_gauge(2, "speedup"), Some(1.5));
        assert!(reg.mean_latency("shard2_predict").is_some());
        assert_eq!(scope.shard(), Some(2));
        // The no-op scope drops everything.
        let none = MetricsScope::none();
        none.incr("never");
        assert!(none.registry().is_none());
        assert_eq!(reg.counter("never"), 0);
    }

    #[test]
    fn span_guards_are_inert_off_and_record_on() {
        let _serial = crate::trace::test_lock();
        let reg = Arc::new(MetricsRegistry::new());
        let collector = Arc::new(crate::trace::SpanCollector::default());
        let scope = MetricsScope::for_shard(reg.clone(), 0).with_spans(collector);

        crate::trace::set_enabled(false);
        drop(scope.span("estimator"));
        assert!(reg.mean_latency("span_estimator").is_none(), "disabled spans record nothing");
        assert!(scope.drain_spans().is_empty());

        crate::trace::set_enabled(true);
        drop(scope.span_with("kernel", Some("masked")));
        drop(scope.span("reply"));
        crate::trace::set_enabled(false);

        // Series land in the shard stripe under span_<label>…
        assert!(reg.mean_latency("shard0_span_kernel_masked").is_some());
        assert!(reg.mean_latency("span_reply").is_some(), "plain key merges the stripe");
        // …and the collector kept the per-batch breakdown, in order.
        let spans = scope.drain_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].label(), "kernel_masked");
        assert_eq!(spans[1].label(), "reply");
        assert!(spans.iter().all(|s| s.micros >= 0.0));

        // A scope with no registry issues inert guards even when enabled.
        crate::trace::set_enabled(true);
        drop(MetricsScope::none().span("never"));
        crate::trace::set_enabled(false);
    }
}
