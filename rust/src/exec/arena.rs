//! Recycled activation buffers for the serving hot path.

/// A pool of recycled activation buffers: the serving hot path allocates
/// nothing per batch after warmup. Each shard executor owns one arena
/// outright — inside its [`super::ExecCtx`], so there is no lock on the
/// per-batch path; the native backend keeps a shared, mutex-guarded arena
/// for callers that predict without an executor context.
pub struct ScratchArena {
    bufs: Vec<Vec<f32>>,
    cap: usize,
}

impl ScratchArena {
    /// Cap on recycled buffers (bounds idle memory; beyond this they are
    /// simply dropped).
    pub const DEFAULT_CAP: usize = 8;

    pub fn new() -> ScratchArena {
        ScratchArena::with_capacity(ScratchArena::DEFAULT_CAP)
    }

    pub fn with_capacity(cap: usize) -> ScratchArena {
        ScratchArena { bufs: Vec::new(), cap: cap.max(1) }
    }

    /// A buffer of exactly `len` elements. Resize only (no clear): every
    /// consumer overwrites the whole buffer, so re-zeroing a recycled prefix
    /// would be pure memset tax.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.bufs.pop().unwrap_or_default();
        buf.resize(len, 0.0);
        buf
    }

    /// Hand a buffer back for reuse (dropped once the arena is full).
    pub fn put(&mut self, buf: Vec<f32>) {
        if self.bufs.len() < self.cap {
            self.bufs.push(buf);
        }
    }

    /// Number of buffers currently parked.
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    /// Merge another arena's buffers into this one, respecting the cap
    /// (shared-arena callers return their borrowed buffers this way).
    pub fn absorb(&mut self, mut other: ScratchArena) {
        while self.bufs.len() < self.cap {
            match other.bufs.pop() {
                Some(buf) => self.bufs.push(buf),
                None => break,
            }
        }
    }
}

impl Default for ScratchArena {
    fn default() -> ScratchArena {
        ScratchArena::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_arena_recycles_and_caps() {
        let mut arena = ScratchArena::with_capacity(2);
        let a = arena.take(8);
        assert_eq!(a.len(), 8);
        arena.put(a);
        arena.put(vec![0.0; 4]);
        arena.put(vec![0.0; 16]); // over cap → dropped
        assert_eq!(arena.len(), 2);
        // Recycled buffer is resized to the requested length.
        let b = arena.take(3);
        assert_eq!(b.len(), 3);
        let mut other = ScratchArena::new();
        other.put(vec![0.0; 1]);
        arena.absorb(other);
        assert_eq!(arena.len(), 2, "absorb respects the cap");
    }
}
