//! A profile's model bound to concrete parameters, executing through PJRT.
//!
//! Owns the parameter state (weights/biases as host matrices + cached device
//! literals), the momentum state for training, and the estimator factors for
//! the `_fwd_ae` artifact. The coordinator drives everything through this
//! type; the SVD refresh itself runs in Rust (`linalg::svd`) — Python stays
//! build-time only.

use super::engine::{
    check_shape, i32_to_literal, literal_to_mat, literal_to_scalar, mat_to_literal,
    scalar_literal, u32_to_literal, vec_to_literal, Engine, ProfileArtifacts,
};
use crate::linalg::{LowRank, Mat};
use crate::nn::Mlp;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Runtime state for one profile.
pub struct ModelRuntime {
    pub engine: Arc<Engine>,
    pub profile: String,
    fwd_name: String,
    fwd_ae_name: String,
    train_name: String,
    pub batch: usize,
    pub layers: Vec<usize>,
    pub ranks: Vec<usize>,
    /// Host copy of parameters: `(w_l, b_l)` per layer.
    pub weights: Vec<Mat>,
    pub biases: Vec<Vec<f32>>,
    /// Cached parameter literals, invalidated on update.
    param_literals: Vec<xla::Literal>,
    /// Momentum buffers (same shapes as params), as literals.
    velocity_literals: Vec<xla::Literal>,
    /// Estimator factors `(U_l, V_l)` per hidden layer, as literals.
    factor_literals: Option<Vec<xla::Literal>>,
    /// Steps taken (feeds the PRNG key for dropout).
    pub step_count: u64,
}

impl ModelRuntime {
    /// Bind a trained/initialized network to a manifest profile.
    pub fn from_mlp(engine: Arc<Engine>, profile: &str, net: &Mlp) -> Result<ModelRuntime> {
        let (layers, batch, ranks, fwd_name, fwd_ae_name, train_name) = {
            let arts = ProfileArtifacts::of(&engine.manifest, profile)?;
            (
                arts.fwd.layers.clone(),
                arts.fwd.batch,
                arts.fwd_ae.ranks.clone(),
                arts.fwd.name.clone(),
                arts.fwd_ae.name.clone(),
                arts.train_step.name.clone(),
            )
        };
        let expect: Vec<usize> = net.layer_sizes();
        if expect != layers {
            return Err(anyhow!(
                "network layers {expect:?} do not match artifact layers {layers:?}"
            ));
        }
        let mut rt = ModelRuntime {
            engine,
            profile: profile.to_string(),
            fwd_name,
            fwd_ae_name,
            train_name,
            batch,
            layers,
            ranks,
            weights: net.weights.clone(),
            biases: net.biases.clone(),
            param_literals: Vec::new(),
            velocity_literals: Vec::new(),
            factor_literals: None,
            step_count: 0,
        };
        rt.rebuild_param_literals()?;
        rt.reset_velocity()?;
        Ok(rt)
    }

    /// Extract the current parameters as a host-side [`Mlp`].
    pub fn to_mlp(&self) -> Mlp {
        Mlp { weights: self.weights.clone(), biases: self.biases.clone() }
    }

    fn rebuild_param_literals(&mut self) -> Result<()> {
        let mut lits = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.biases) {
            lits.push(mat_to_literal(w)?);
            lits.push(vec_to_literal(b));
        }
        self.param_literals = lits;
        Ok(())
    }

    /// Zero the momentum buffers.
    pub fn reset_velocity(&mut self) -> Result<()> {
        let mut lits = Vec::with_capacity(self.weights.len() * 2);
        for (w, b) in self.weights.iter().zip(&self.biases) {
            lits.push(mat_to_literal(&Mat::zeros(w.rows(), w.cols()))?);
            lits.push(vec_to_literal(&vec![0.0; b.len()]));
        }
        self.velocity_literals = lits;
        Ok(())
    }

    /// Recompute estimator factors from the current weights by truncated SVD
    /// at the manifest's ranks — the paper's refresh, owned by Rust.
    pub fn refresh_factors(&mut self) -> Result<()> {
        let mut lits = Vec::new();
        for (l, &rank) in self.ranks.iter().enumerate() {
            let lr = LowRank::truncate(&self.weights[l], rank);
            lits.push(mat_to_literal(&lr.u)?);
            lits.push(mat_to_literal(&lr.v)?);
        }
        self.factor_literals = Some(lits);
        Ok(())
    }

    /// Pad a sub-batch up to the artifact's fixed batch size.
    fn pad_batch(&self, x: &Mat) -> Result<Mat> {
        if x.cols() != self.layers[0] {
            return Err(anyhow!(
                "input dim {} != model input {}",
                x.cols(),
                self.layers[0]
            ));
        }
        if x.rows() > self.batch {
            return Err(anyhow!("batch {} exceeds artifact batch {}", x.rows(), self.batch));
        }
        if x.rows() == self.batch {
            return Ok(x.clone());
        }
        Ok(x.vstack(&Mat::zeros(self.batch - x.rows(), x.cols())))
    }

    /// Control forward through the `_fwd` artifact. Accepts up to `batch`
    /// rows; returns exactly `x.rows()` rows of logits.
    pub fn forward(&self, x: &Mat) -> Result<Mat> {
        let n = x.rows();
        let x_lit = mat_to_literal(&self.pad_batch(x)?)?;
        let mut inputs: Vec<&xla::Literal> = self.param_literals.iter().collect();
        inputs.push(&x_lit);
        let out = self.engine.execute(&self.fwd_name, &inputs)?;
        let logits = literal_to_mat(&out[0])?;
        Ok(logits.rows_slice(0, n))
    }

    /// Estimator-augmented forward through the `_fwd_ae` artifact
    /// (Pallas sign-estimator + tile-masked matmul inside the HLO).
    pub fn forward_ae(&self, x: &Mat) -> Result<Mat> {
        let factors = self
            .factor_literals
            .as_ref()
            .ok_or_else(|| anyhow!("call refresh_factors() before forward_ae()"))?;
        let n = x.rows();
        let x_lit = mat_to_literal(&self.pad_batch(x)?)?;
        let mut inputs: Vec<&xla::Literal> = self.param_literals.iter().collect();
        inputs.extend(factors.iter());
        inputs.push(&x_lit);
        let out = self.engine.execute(&self.fwd_ae_name, &inputs)?;
        let logits = literal_to_mat(&out[0])?;
        Ok(logits.rows_slice(0, n))
    }

    /// One SGD-momentum minibatch through the `_train_step` artifact.
    /// `x` must be exactly the artifact batch; labels in `[0, classes)`.
    /// Updates the parameter and velocity literals in place; returns loss.
    pub fn train_step(&mut self, x: &Mat, y: &[usize], lr: f32, momentum: f32) -> Result<f32> {
        if x.rows() != self.batch {
            return Err(anyhow!(
                "train_step requires a full batch of {} (got {})",
                self.batch,
                x.rows()
            ));
        }
        let spec = self
            .engine
            .manifest
            .artifact(&self.train_name)
            .ok_or_else(|| anyhow!("missing train artifact"))?;
        check_shape(x, spec.inputs.iter().find(|a| a.name == "x").unwrap())?;

        let labels: Vec<i32> = y.iter().map(|&v| v as i32).collect();
        let key = [0xC0DEu32, self.step_count as u32];
        let x_lit = mat_to_literal(x)?;
        let y_lit = i32_to_literal(&labels);
        let key_lit = u32_to_literal(&key);
        let lr_lit = scalar_literal(lr);
        let mom_lit = scalar_literal(momentum);
        let mut inputs: Vec<&xla::Literal> = self.param_literals.iter().collect();
        inputs.extend(self.velocity_literals.iter());
        inputs.extend([&x_lit, &y_lit, &key_lit, &lr_lit, &mom_lit]);

        let out = self.engine.execute(&self.train_name, &inputs)?;
        let n_params = self.param_literals.len();
        if out.len() != 2 * n_params + 1 {
            return Err(anyhow!(
                "train_step returned {} outputs, expected {}",
                out.len(),
                2 * n_params + 1
            ));
        }
        // Refresh host + literal copies of params and velocities.
        let mut out = out;
        let loss = literal_to_scalar(&out[2 * n_params])?;
        for (i, lit) in out.drain(..).take(2 * n_params).enumerate() {
            if i < n_params {
                let m = literal_to_mat(&lit)?;
                if i % 2 == 0 {
                    self.weights[i / 2] = m;
                } else {
                    self.biases[i / 2] = m.into_vec();
                }
                self.param_literals[i] = lit;
            } else {
                self.velocity_literals[i - n_params] = lit;
            }
        }
        self.step_count += 1;
        Ok(loss)
    }
}

// PJRT-dependent tests live in rust/tests/runtime_roundtrip.rs so the unit
// suite stays device-free.
