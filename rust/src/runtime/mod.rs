//! The AOT bridge: load HLO-text artifacts produced by `python/compile/aot.py`
//! and execute them on the PJRT CPU client via the `xla` crate.
//!
//! Python never runs at request time — `make artifacts` is the only Python
//! invocation; afterwards the Rust binary is self-contained.
//!
//! - [`manifest`] — typed view of `artifacts/manifest.json`.
//! - [`engine`] — PJRT client + compile-once executable cache.
//! - [`model_runtime`] — a profile's networks bound to concrete parameters:
//!   forward (control), forward (estimator-augmented), and train-step.

pub mod manifest;
pub mod engine;
pub mod model_runtime;

pub use engine::Engine;
pub use manifest::{ArgSpec, ArtifactSpec, Manifest};
pub use model_runtime::ModelRuntime;
