//! Typed view of `artifacts/manifest.json` (written by `python/compile/aot.py`).

use crate::io::json::Json;
use std::path::{Path, PathBuf};

/// One input/output argument of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
    pub batch: usize,
    pub layers: Vec<usize>,
    pub ranks: Vec<usize>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|a| a.name == name)
    }
}

/// The whole manifest: artifact specs grouped by profile.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub profiles: Vec<(String, Vec<ArtifactSpec>)>,
}

fn parse_arg(v: &Json) -> Option<ArgSpec> {
    Some(ArgSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Option<Vec<_>>>()?,
        dtype: v
            .get("dtype")
            .and_then(|d| d.as_str())
            .unwrap_or("f32")
            .to_string(),
    })
}

fn parse_usize_arr(v: Option<&Json>) -> Vec<usize> {
    v.and_then(|a| a.as_arr())
        .map(|items| items.iter().filter_map(|d| d.as_usize()).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("cannot read manifest in {dir:?}: {e} — run `make artifacts` first"))?;
        let root = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let profiles_obj = root
            .get("profiles")
            .and_then(|p| p.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'profiles'"))?;
        let mut profiles = Vec::new();
        for (pname, entries) in profiles_obj {
            let mut specs = Vec::new();
            for e in entries.as_arr().unwrap_or(&[]) {
                let spec = (|| -> Option<ArtifactSpec> {
                    Some(ArtifactSpec {
                        name: e.get("name")?.as_str()?.to_string(),
                        file: e.get("file")?.as_str()?.to_string(),
                        inputs: e
                            .get("inputs")?
                            .as_arr()?
                            .iter()
                            .map(parse_arg)
                            .collect::<Option<Vec<_>>>()?,
                        outputs: e
                            .get("outputs")?
                            .as_arr()?
                            .iter()
                            .map(parse_arg)
                            .collect::<Option<Vec<_>>>()?,
                        batch: e.get("batch")?.as_usize()?,
                        layers: parse_usize_arr(e.get("layers")),
                        ranks: parse_usize_arr(e.get("ranks")),
                    })
                })()
                .ok_or_else(|| anyhow::anyhow!("malformed artifact entry in profile {pname}"))?;
                specs.push(spec);
            }
            profiles.push((pname.clone(), specs));
        }
        Ok(Manifest { dir: dir.to_path_buf(), profiles })
    }

    /// All artifacts of one profile.
    pub fn profile(&self, name: &str) -> Option<&[ArtifactSpec]> {
        self.profiles
            .iter()
            .find(|(p, _)| p == name)
            .map(|(_, specs)| specs.as_slice())
    }

    /// Find one artifact by full name (e.g. `mnist_tiny_fwd`).
    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.profiles
            .iter()
            .flat_map(|(_, specs)| specs.iter())
            .find(|s| s.name == name)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_dir() -> PathBuf {
        let dir = std::env::temp_dir().join("condcomp-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","version":1,"profiles":{"tiny":[
                {"name":"tiny_fwd","file":"tiny_fwd.hlo.txt","batch":4,
                 "layers":[8,6,3],
                 "inputs":[{"name":"w0","shape":[8,6],"dtype":"f32"},
                            {"name":"b0","shape":[6],"dtype":"f32"},
                            {"name":"x","shape":[4,8],"dtype":"f32"}],
                 "outputs":[{"name":"logits","shape":[4,3],"dtype":"f32"}]}
            ]}}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_and_indexes() {
        let m = Manifest::load(&fixture_dir()).unwrap();
        assert_eq!(m.profiles.len(), 1);
        let spec = m.artifact("tiny_fwd").unwrap();
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.layers, vec![8, 6, 3]);
        assert_eq!(spec.input_index("x"), Some(2));
        assert_eq!(spec.inputs[0].element_count(), 48);
        assert!(m.path_of(spec).ends_with("tiny_fwd.hlo.txt"));
        assert!(m.profile("tiny").is_some());
        assert!(m.profile("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_if_present() {
        // When the repo's artifacts have been built, validate the real thing.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let fwd = m.artifact("mnist_tiny_fwd").expect("mnist_tiny_fwd in manifest");
            assert_eq!(fwd.inputs.last().unwrap().name, "x");
            assert!(m.artifact("mnist_tiny_train_step").is_some());
            assert!(m.artifact("mnist_tiny_fwd_ae").is_some());
        }
    }
}
