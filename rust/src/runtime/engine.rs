//! PJRT client wrapper with a compile-once executable cache.

use super::manifest::{ArtifactSpec, Manifest};
use crate::linalg::Mat;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A loaded PJRT CPU engine over one artifacts directory.
///
/// Executables are compiled lazily on first use and cached by artifact name;
/// compilation happens once per process, execution is the hot path. The
/// cache is mutex-guarded so the engine can be shared across server threads.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU engine over `dir` (must contain `manifest.json`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Fetch (compiling if needed) the executable for an artifact.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with positional literal inputs (borrowed — no
    /// copies on the hot path); returns the decomposed output tuple.
    pub fn execute(&self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True.
        out.to_tuple().map_err(|e| anyhow!("untuple result of {name}: {e:?}"))
    }

    /// Number of compiled executables currently cached.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

// ---------------------------------------------------------------------------
// Literal marshalling helpers
// ---------------------------------------------------------------------------

/// Row-major `Mat` → rank-2 f32 literal.
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    xla::Literal::vec1(m.as_slice())
        .reshape(&[m.rows() as i64, m.cols() as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Rank-1 f32 literal from a slice.
pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Rank-1 i32 literal (labels).
pub fn i32_to_literal(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Rank-1 u32 literal (PRNG keys).
pub fn u32_to_literal(v: &[u32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 scalar literal.
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Rank-2 f32 literal → `Mat` (shape taken from the literal).
pub fn literal_to_mat(lit: &xla::Literal) -> Result<Mat> {
    let shape = lit.array_shape().map_err(|e| anyhow!("literal shape: {e:?}"))?;
    let dims = shape.dims();
    let data: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal data: {e:?}"))?;
    match dims.len() {
        2 => Ok(Mat::from_vec(dims[0] as usize, dims[1] as usize, data)),
        1 => Ok(Mat::from_vec(1, dims[0] as usize, data)),
        0 => Ok(Mat::from_vec(1, 1, data)),
        d => Err(anyhow!("expected rank <= 2 literal, got rank {d}")),
    }
}

/// Scalar f32 from a rank-0 literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar literal: {e:?}"))
}

/// Check a `Mat` against an [`super::manifest::ArgSpec`] shape.
pub fn check_shape(m: &Mat, spec: &super::manifest::ArgSpec) -> Result<()> {
    let want: Vec<usize> = spec.shape.clone();
    let got = vec![m.rows(), m.cols()];
    let ok = match want.len() {
        2 => got == want,
        1 => m.rows() == 1 && m.cols() == want[0],
        _ => false,
    };
    if ok {
        Ok(())
    } else {
        Err(anyhow!("argument '{}' expects shape {want:?}, got {got:?}", spec.name))
    }
}

/// Convenience: find the specs of a profile, split by role.
pub struct ProfileArtifacts<'a> {
    pub fwd: &'a ArtifactSpec,
    pub fwd_ae: &'a ArtifactSpec,
    pub train_step: &'a ArtifactSpec,
}

impl<'a> ProfileArtifacts<'a> {
    pub fn of(manifest: &'a Manifest, profile: &str) -> Result<ProfileArtifacts<'a>> {
        let specs = manifest
            .profile(profile)
            .ok_or_else(|| anyhow!("profile '{profile}' not in manifest"))?;
        let find = |suffix: &str| {
            specs
                .iter()
                .find(|s| s.name.ends_with(suffix))
                .ok_or_else(|| anyhow!("profile '{profile}' missing *{suffix}"))
        };
        Ok(ProfileArtifacts {
            fwd: specs
                .iter()
                .find(|s| s.name.ends_with("_fwd"))
                .ok_or_else(|| anyhow!("profile '{profile}' missing *_fwd"))?,
            fwd_ae: find("_fwd_ae")?,
            train_step: find("_train_step")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn mat_literal_roundtrip() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(5, 3, 1.0, &mut rng);
        let lit = mat_to_literal(&m).unwrap();
        let back = literal_to_mat(&lit).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_literal(3.25);
        assert_eq!(literal_to_scalar(&lit).unwrap(), 3.25);
    }

    #[test]
    fn shape_check() {
        use crate::runtime::manifest::ArgSpec;
        let m = Mat::zeros(4, 8);
        let ok = ArgSpec { name: "x".into(), shape: vec![4, 8], dtype: "f32".into() };
        let bad = ArgSpec { name: "x".into(), shape: vec![8, 4], dtype: "f32".into() };
        assert!(check_shape(&m, &ok).is_ok());
        assert!(check_shape(&m, &bad).is_err());
        let v = Mat::zeros(1, 8);
        let vec_spec = ArgSpec { name: "b".into(), shape: vec![8], dtype: "f32".into() };
        assert!(check_shape(&v, &vec_spec).is_ok());
    }
}
