//! A minimal, offline stand-in for the `anyhow` crate.
//!
//! Implements exactly the slice of the API this workspace uses:
//!
//! - [`Error`] — an opaque, `Send + Sync` error value rendered from whatever
//!   produced it (message string preserved; the source chain is flattened at
//!   conversion time).
//! - [`Result<T>`] — alias with `Error` as the default error type.
//! - [`anyhow!`] — `format!`-style error constructor.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

// Vendored API-compatibility shim: mirrors the upstream crate's surface, so
// it is exempt from the workspace clippy gate.
#![allow(clippy::all)]

use std::fmt;

/// An opaque error: a rendered message (plus any flattened source chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` macro calls this).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Create from a std error, flattening its source chain into the message.
    pub fn new<E: std::error::Error>(err: E) -> Error {
        let mut msg = err.to_string();
        let mut src = err.source();
        while let Some(cause) = src {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            src = cause.source();
        }
        Error { msg }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::new(err)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `format!`-style [`Error`] constructor, mirroring `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad value {} in {}", 7, "layer");
        assert_eq!(e.to_string(), "bad value 7 in layer");
        let e2 = anyhow!("plain");
        assert_eq!(format!("{e2:?}"), "plain");
    }
}
