//! API stub for the `xla` (PJRT) bindings used by `condcomp::runtime`.
//!
//! The offline build environment has neither crates.io nor a PJRT plugin, so
//! this crate provides the exact API surface the runtime layer compiles
//! against, split in two tiers:
//!
//! - **Literal marshalling is real.** [`Literal`] stores typed host buffers
//!   with shapes, and `vec1` / `scalar` / `reshape` / `to_vec` /
//!   `array_shape` / `to_tuple` / `get_first_element` behave like the real
//!   crate — the engine's marshalling helpers and their unit tests run
//!   unchanged.
//! - **Device execution is unavailable.** [`PjRtClient::cpu`] returns
//!   [`XlaError::Unavailable`], so `Engine::load` fails with a clear message
//!   and everything downstream (the PJRT backend, `train-pjrt`, the artifact
//!   round-trip tests) reports "PJRT unavailable" instead of linking against
//!   a library that is not there. Swapping this path dependency for the real
//!   bindings re-enables the whole three-layer pipeline without touching
//!   `condcomp` source.

// Vendored API-compatibility stub: mirrors the upstream crate's surface, so
// it is exempt from the workspace clippy gate.
#![allow(clippy::all)]

use std::path::Path;

/// Error type mirroring the real crate's; only the variants the workspace
/// can actually hit are modelled.
#[derive(Debug, Clone, PartialEq)]
pub enum XlaError {
    /// The stub cannot perform device work.
    Unavailable(&'static str),
    /// Shape/type mismatch in literal marshalling.
    Shape(String),
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XlaError::Unavailable(what) => {
                write!(f, "PJRT unavailable in this build (stub xla crate): {what}")
            }
            XlaError::Shape(msg) => write!(f, "literal shape error: {msg}"),
        }
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

// ---------------------------------------------------------------------------
// Literals (functional)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
            Data::Tuple(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn into_data(v: Vec<Self>) -> Data;
    fn as_slice(data: &Data) -> Option<&[Self]>;
}

macro_rules! native {
    ($t:ty, $variant:ident) => {
        impl NativeType for $t {
            fn into_data(v: Vec<Self>) -> Data {
                Data::$variant(v)
            }
            fn as_slice(data: &Data) -> Option<&[Self]> {
                match data {
                    Data::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32);
native!(i32, I32);
native!(u32, U32);

/// A host-side typed array with a shape (row-major).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::into_data(v.to_vec()) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::into_data(vec![v]) }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count as usize != self.data.len() || dims.iter().any(|&d| d < 0) {
            return Err(XlaError::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out the host buffer as `Vec<T>`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::as_slice(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| XlaError::Shape("literal element type mismatch".into()))
    }

    /// Shape of a (non-tuple) array literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            Data::Tuple(_) => Err(XlaError::Shape("tuple literal has no array shape".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(v) => Ok(v),
            _ => Err(XlaError::Shape("literal is not a tuple".into())),
        }
    }

    /// Build a tuple literal (round-trip convenience for tests).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { dims: vec![elements.len() as i64], data: Data::Tuple(elements) }
    }

    /// First element of the buffer (scalars and debugging).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::as_slice(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| XlaError::Shape("empty or mistyped literal".into()))
    }
}

/// Array shape (dims only; dtype is implied by the literal).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// Client / compilation / execution (unavailable in the stub)
// ---------------------------------------------------------------------------

/// Parsed HLO module; the stub never parses, it reports unavailability.
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(XlaError::Unavailable("HLO text parsing"))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Construction always fails in the stub.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(XlaError::Unavailable(
            "no PJRT plugin in this build; link the real xla crate to enable",
        ))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(XlaError::Unavailable("compilation"))
    }
}

/// A compiled executable handle (unreachable through the stub client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError::Unavailable("execution"))
    }
}

/// A device buffer handle (unreachable through the stub client).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(XlaError::Unavailable("device-to-host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.array_shape().unwrap().dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn typed_access_is_checked() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.get_first_element::<i32>().unwrap(), 1);
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::tuple(vec![Literal::scalar(1.5f32), Literal::vec1(&[2u32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].get_first_element::<f32>().unwrap(), 1.5);
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(matches!(err, XlaError::Unavailable(_)));
        assert!(err.to_string().contains("PJRT unavailable"));
    }
}
