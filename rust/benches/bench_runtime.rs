//! PJRT runtime benches: per-call latency of the three AOT artifacts
//! (control forward, estimator-augmented forward, train step) on the tiny
//! profile. Requires `make artifacts`.
//!
//! `cargo bench --bench bench_runtime`

use condcomp::bench::{bench_with_units, header, BenchConfig};
use condcomp::config::NetConfig;
use condcomp::linalg::Mat;
use condcomp::nn::Mlp;
use condcomp::runtime::{Engine, ModelRuntime};
use condcomp::util::Pcg32;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let cfg = BenchConfig { warmup_s: 0.2, measure_s: 1.0, min_iters: 5, max_iters: 300 };
    let engine = Arc::new(Engine::load(dir).expect("engine"));
    let mut rng = Pcg32::seeded(5);
    let net = Mlp::init(
        &NetConfig { layers: vec![784, 64, 48, 32, 10], weight_sigma: 0.05, bias_init: 0.5 },
        &mut rng,
    );
    let mut rt = ModelRuntime::from_mlp(engine, "mnist-tiny", &net).expect("bind");
    rt.refresh_factors().expect("factors");
    let batch = rt.batch;
    let x = Mat::randn(batch, 784, 0.5, &mut rng);
    let y: Vec<usize> = (0..batch).map(|_| rng.index(10)).collect();

    header(&format!("PJRT artifact execution (batch {batch})"));
    {
        let r = bench_with_units("fwd (control)", &cfg, batch as f64, || rt.forward(&x).unwrap());
        println!("{}", r.line());
    }
    {
        let r = bench_with_units("fwd_ae (estimator+masked)", &cfg, batch as f64, || {
            rt.forward_ae(&x).unwrap()
        });
        println!("{}", r.line());
    }
    {
        let r = bench_with_units("train_step", &cfg, batch as f64, || {
            rt.train_step(&x, &y, 0.05, 0.5).unwrap()
        });
        println!("{}", r.line());
    }
    {
        let r = bench_with_units("svd factor refresh (rust)", &cfg, 1.0, || {
            rt.refresh_factors().unwrap()
        });
        println!("{}", r.line());
    }
}
