//! SVD refresh cost: exact one-sided Jacobi vs the randomized range finder
//! (§5 "online approach") across the paper's layer shapes. This is the
//! once-per-epoch overhead amortized by β in Eq. 9.
//!
//! `cargo bench --bench bench_svd`

use condcomp::bench::{bench, header, BenchConfig};
use condcomp::linalg::{LowRank, Mat, Svd};
use condcomp::util::Pcg32;

fn main() {
    let cfg = BenchConfig { warmup_s: 0.1, measure_s: 0.6, min_iters: 3, max_iters: 50 };
    let mut rng = Pcg32::seeded(11);

    header("estimator refresh: exact SVD vs randomized (rank = 5% of width)");
    for &(d, h) in &[(256usize, 128usize), (784, 256), (300, 180)] {
        let w = Mat::randn(d, h, 0.05, &mut rng);
        let k = (d.min(h) / 20).max(1);
        let exact = bench(&format!("jacobi svd {d}x{h}"), &cfg, || Svd::compute(&w));
        println!("{}", exact.line());
        let trunc = bench(&format!("truncate {d}x{h} k={k}"), &cfg, || LowRank::truncate(&w, k));
        println!("{}", trunc.line());
        let mut rng2 = Pcg32::seeded(5);
        let rand = bench(&format!("randomized {d}x{h} k={k}"), &cfg, || {
            LowRank::randomized(&w, k, 8, &mut rng2)
        });
        println!(
            "{}   vs exact {:.1}×",
            rand.line(),
            trunc.time.median / rand.time.median
        );
    }
}
