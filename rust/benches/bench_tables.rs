//! Regenerate the paper's Tables 2 & 3 (and their companion Figures 3 & 5)
//! at tiny scale: the full sweep of estimator configurations trained end to
//! end, control included — printing the same rows the paper reports.
//!
//! `cargo bench --bench bench_tables`

use condcomp::bench::header;
use condcomp::config::ExperimentProfile;
use condcomp::util::timer::timed;

fn main() {
    let out = std::path::Path::new("results").join("bench-tiny");
    std::fs::create_dir_all(&out).unwrap();

    header("Table 3 / Figure 5 (MNIST-like, tiny profile)");
    let mut mnist = ExperimentProfile::mnist_tiny();
    mnist.train.epochs = 3;
    mnist.n_train = 600;
    mnist.n_valid = 150;
    mnist.n_test = 150;
    let (res, secs) = timed(|| condcomp::experiments::run("table3", &mnist, &out));
    res.expect("table3");
    println!("table3+fig5 regenerated in {secs:.1}s");
    print_table(&out.join("table3.csv"));

    header("Table 2 / Figure 3 (SVHN-like, tiny profile)");
    let mut svhn = ExperimentProfile::svhn_tiny();
    svhn.train.epochs = 2;
    svhn.n_train = 400;
    svhn.n_valid = 100;
    svhn.n_test = 100;
    let (res, secs) = timed(|| condcomp::experiments::run("table2", &svhn, &out));
    res.expect("table2");
    println!("table2+fig3 regenerated in {secs:.1}s");
    print_table(&out.join("table2.csv"));
}

fn print_table(path: &std::path::Path) {
    if let Ok(text) = std::fs::read_to_string(path) {
        for line in text.lines() {
            let mut cells = line.split(',');
            let name = cells.next().unwrap_or("");
            let err = cells.next().unwrap_or("");
            if let Ok(e) = err.parse::<f64>() {
                println!("  {name:<16} {:.2}%", e * 100.0);
            } else {
                println!("  {name:<16} {err}");
            }
        }
    }
}
