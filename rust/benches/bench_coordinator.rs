//! L3 coordinator benches: dynamic-batcher throughput, end-to-end server
//! round-trip latency over TCP, and estimator-refresh cost under serving.
//!
//! `cargo bench --bench bench_coordinator`

use condcomp::bench::{bench, bench_with_units, header, BenchConfig};
use condcomp::config::{EstimatorConfig, ExperimentProfile, NetConfig};
use condcomp::coordinator::protocol::Mode;
use condcomp::coordinator::server::Client;
use condcomp::coordinator::{Backend, NativeBackend, Server, ServerConfig};
use condcomp::estimator::SignEstimatorSet;
use condcomp::linalg::Mat;
use condcomp::nn::Mlp;
use condcomp::util::Pcg32;
use std::sync::Arc;

fn main() {
    let cfg = BenchConfig { warmup_s: 0.1, measure_s: 0.8, min_iters: 5, max_iters: 500 };
    let mut rng = Pcg32::seeded(3);
    let profile = ExperimentProfile::mnist_tiny();

    // Backend under test.
    let net = Mlp::init(
        &NetConfig { layers: profile.net.layers.clone(), weight_sigma: 0.05, bias_init: 0.5 },
        &mut rng,
    );
    let est = SignEstimatorSet::fit(&net, &EstimatorConfig::fixed(&[8, 6, 4]), 7);
    let backend = Arc::new(NativeBackend::new(net.clone(), est, 64));

    header("backend predict (no networking)");
    for rows in [1usize, 16, 64] {
        let x = Mat::randn(rows, 784, 0.5, &mut rng);
        for mode in [Mode::Control, Mode::ConditionalAe] {
            let b = backend.clone();
            let xx = x.clone();
            let r = bench_with_units(
                &format!("predict {} rows={rows}", mode.as_str()),
                &cfg,
                rows as f64,
                move || b.predict(&xx, mode).unwrap(),
            );
            println!("{}", r.line());
        }
    }

    header("estimator refresh (SVD over all hidden layers)");
    let b = backend.clone();
    let r = bench("refresh", &cfg, move || b.refresh().unwrap());
    println!("{}", r.line());

    header("server round-trip over TCP (single client, batch-of-1)");
    let server = Server::start(
        backend,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            max_wait: std::time::Duration::from_millis(1),
            shards: 1,
            ..ServerConfig::default()
        },
    )
    .expect("server");
    let addr = server.local_addr;
    let x = Mat::randn(1, 784, 0.5, &mut rng);
    for mode in [Mode::Control, Mode::ConditionalAe] {
        let mut client = Client::connect(&addr).unwrap();
        let xx = x.clone();
        let r = bench_with_units(
            &format!("tcp predict {}", mode.as_str()),
            &cfg,
            1.0,
            move || {
                // Note: includes JSON encode/decode + TCP + batching window.
                client_predict(&mut client, xx.clone(), mode)
            },
        );
        println!("{}", r.line());
    }
    println!(
        "server processed {} predictions in {} batches",
        server.metrics.counter("predictions"),
        server.metrics.counter("batches"),
    );
    server.shutdown();
}

fn client_predict(client: &mut Client, x: Mat, mode: Mode) {
    let resp = client.predict(x, mode).unwrap();
    assert!(resp.ok);
}
