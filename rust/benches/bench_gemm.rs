//! Hot-path microbenches: dense GEMM (naive vs blocked vs pool-parallel),
//! the conditional masked GEMM across the sparsity sweep (the measured side
//! of Eq. 10), the low-rank estimator product, and the full
//! dense-vs-masked-vs-parallel sweep (α × thread grid) with the measured
//! dispatch threshold.
//!
//! `cargo bench --bench bench_gemm`

use condcomp::bench::{bench_with_units, header, sweep, BenchConfig};
use condcomp::condcomp::MaskedLayer;
use condcomp::linalg::gemm::{matmul, matmul_naive, matmul_par};
use condcomp::linalg::{LowRank, Mat};
use condcomp::parallel::{default_threads, ThreadPool};
use condcomp::util::Pcg32;

fn main() {
    let cfg = BenchConfig::default();
    let mut rng = Pcg32::seeded(7);

    header("dense GEMM (batch 64, layer-1 of the paper MNIST net)");
    let (m, d, h) = (64usize, 784usize, 1000usize);
    let a = Mat::randn(m, d, 1.0, &mut rng);
    let b = Mat::randn(d, h, 0.05, &mut rng);
    let flops = (2 * m * d * h) as f64;
    let naive = bench_with_units("matmul_naive 64x784x1000", &cfg, flops, || matmul_naive(&a, &b));
    println!("{}", naive.line());
    let blocked = bench_with_units("matmul_blocked 64x784x1000", &cfg, flops, || matmul(&a, &b));
    println!("{}", blocked.line());
    println!(
        "blocked vs naive: {:.2}×",
        naive.time.median / blocked.time.median
    );
    let threads = default_threads();
    let pool = ThreadPool::new(threads);
    let par = bench_with_units(
        &format!("matmul_par 64x784x1000 threads={threads}"),
        &cfg,
        flops,
        || matmul_par(&a, &b, &pool),
    );
    println!(
        "{}   parallel vs blocked {:.2}×",
        par.line(),
        blocked.time.median / par.time.median
    );

    header("conditional masked GEMM vs density α (same layer)");
    let bias = vec![0.0f32; h];
    let layer = MaskedLayer::new(&b, &bias);
    let dense = bench_with_units("forward_dense", &cfg, flops, || layer.forward_dense(&a));
    println!("{}", dense.line());
    for alpha in [0.05f32, 0.1, 0.25, 0.5, 1.0] {
        let mask = Mat::from_fn(m, h, |_, _| if rng.bernoulli(alpha) { 1.0 } else { 0.0 });
        let r = bench_with_units(
            &format!("forward_masked α={alpha}"),
            &cfg,
            flops * alpha as f64,
            || layer.forward_masked(&a, &mask),
        );
        println!(
            "{}   speedup vs dense {:.2}×",
            r.line(),
            dense.time.median / r.time.median
        );
    }

    header("estimator low-rank product a·U·V (rank sweep)");
    for k in [10usize, 25, 50, 100] {
        let lr = LowRank::truncate(&b, k);
        let mut tmp = Mat::zeros(m, k);
        let mut out = Mat::zeros(m, h);
        let est_flops = (2 * m * d * k + 2 * m * k * h) as f64;
        let r = bench_with_units(&format!("lowrank apply k={k}"), &cfg, est_flops, || {
            lr.apply_into(&a, &mut tmp, &mut out)
        });
        println!(
            "{}   overhead vs dense {:.1}%",
            r.line(),
            100.0 * r.time.median / dense.time.median
        );
    }

    header("dense-vs-masked-vs-parallel sweep (α × threads grid, 512³ dense)");
    let quick = condcomp::bench::quick();
    let layer_sizes = condcomp::config::ExperimentProfile::mnist_small().net.layers;
    let result = sweep::run_parallel_sweep(&quick, 512, 64, threads, &layer_sizes, None);
    for line in result.report_lines() {
        println!("{line}");
    }
}
