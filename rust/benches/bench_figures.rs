//! Regenerate the paper's standalone figures (2, 4, 6) and the §3.4 speedup
//! sweep at tiny scale, timing each driver. The same drivers run at full
//! scale via `condcomp experiment <id> --profile mnist-small`.
//!
//! `cargo bench --bench bench_figures`

use condcomp::bench::header;
use condcomp::config::ExperimentProfile;
use condcomp::util::timer::timed;

fn tiny() -> ExperimentProfile {
    let mut p = ExperimentProfile::mnist_tiny();
    p.train.epochs = 3;
    p.n_train = 600;
    p.n_valid = 150;
    p.n_test = 150;
    p
}

fn main() {
    let out = std::path::Path::new("results").join("bench-tiny");
    std::fs::create_dir_all(&out).unwrap();
    let profile = tiny();

    header("figure drivers (tiny profile; see results/bench-tiny/*.csv)");
    for id in ["fig2", "fig4", "fig6", "speedup"] {
        let (res, secs) = timed(|| condcomp::experiments::run(id, &profile, &out));
        res.unwrap_or_else(|e| panic!("{id}: {e}"));
        println!("{id:<10} regenerated in {secs:.1}s");
    }
    println!("\nrows written under {}", out.display());
}
